//! The serve loop: a `TcpListener` accept thread feeding the scheduler
//! thread through an mpsc command queue.
//!
//! ## Threading model
//!
//! * **Scheduler thread** (the caller of [`Server::run`]) — owns the
//!   [`Scheduler`], every session in it, and ALL session bookkeeping
//!   (lifecycle transitions, manifest rewrites, watch pushes). With
//!   `serve.steppers = 1` the optimization work happens here too, one
//!   session-iteration per quantum.
//! * **Stepper workers** (`serve.steppers > 1`, ISSUE 8) — run whole
//!   quanta dispatched by the scheduler: a session's driver is handed to
//!   a worker for one `Driver::iteration` and handed back with the
//!   outcome, so up to `steppers` sessions step simultaneously, each on
//!   its arbited width (Σ grants ≤ physical). Workers never touch the
//!   session table; a completion wakes this thread through the command
//!   queue (`ConnMsg::Wake`).
//! * **Accept thread** — blocks on `accept`, spawns one reader thread
//!   per connection. Woken for exit by a self-connect at shutdown.
//! * **Reader threads** (one per connection) — parse one JSONL request
//!   per line and ship `(request, line_tx, proto)` to the scheduler,
//!   where `line_tx` is the connection's long-lived outbound line queue
//!   and `proto` its negotiated protocol version. The `hello`
//!   handshake (ISSUE 10) is resolved HERE, between reads, so the
//!   version bind strictly precedes every later line's parse — its
//!   reply still rides the command queue to keep response order.
//! * **Writer threads** (one per connection, ISSUE 5) — drain that
//!   queue onto the socket. Request responses AND `watch` pushes flow
//!   through the same queue, so everything a connection sees is written
//!   by one thread, in one total order.
//!
//! The command queue is drained *before every scheduler pump*, so
//! protocol latency is bounded by one session iteration (serial) or by
//! one non-blocking dispatch/reap pass (concurrent — lifecycle commands
//! on a session whose quantum is in flight additionally settle that one
//! quantum first). All of a connection's requests — including
//! unparseable lines, which travel the queue as pre-failed commands —
//! are answered in arrival order; `watch` pushes interleave between
//! responses and are distinguished by their `event` field. Watch pushes
//! for a given session are emitted in that session's iteration order:
//! completions reattach on this thread one at a time, and a session
//! never has two quanta in flight, so per-session push order is
//! preserved under any stepper interleaving (pushes of *different*
//! sessions may interleave in completion order — they always could).
//!
//! ## Result streaming
//!
//! `watch` registers the connection's line queue against a session id.
//! After every quantum the scheduler pushes an `{"event":"iter",...}`
//! record each `stream_every` completed iterations of a watched
//! session, and an `{"event":"result",...}` terminal record when it
//! finishes — including finishes that happen outside a quantum (client
//! `cancel`, failed `resume`). Dead subscribers (hung-up clients) are
//! pruned on send failure; a watch on an already-finished session
//! pushes its terminal record immediately.
//!
//! Shutdown: the `shutdown` command is acknowledged, the queue stops
//! being served, and the accept thread is woken to exit. In-flight
//! sessions are dropped with the scheduler — but since every mutation
//! rewrote `ckpt_dir/manifest.jsonl`, a successor server started with
//! `--adopt` re-registers them (suspended sessions resume
//! bit-identically; live ones re-run from their seeds). The same
//! manifest is why binding a NON-empty ckpt_dir without `--adopt` is
//! refused: a fresh server would hand out session ids that collide with
//! the previous server's checkpoints (the ISSUE-4 id-reuse hazard,
//! closed in ISSUE 5).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::obs::{expo, BurstLog, Counter, Gauge, Registry};
use crate::serve::manifest;
use crate::serve::protocol::{self, ErrCode, Proto, Request};
use crate::serve::scheduler::Scheduler;
use crate::serve::session::Session;

/// Hard cap on one request line (a `submit` with a large config object
/// is well under 1 KiB; 1 MiB leaves room without letting a client
/// stream an endless newline-free line into server memory).
const MAX_LINE_BYTES: u64 = 1 << 20;

// NOTE: the connection cap moved to config (`serve.max_conns`, default
// 256) so the untrusted-client hygiene tests can exercise cap behavior
// without opening hundreds of sockets. MAX_LINE_BYTES stays a const:
// the memory bound per connection is a server invariant, not tuning.

/// What a connection's reader thread ships to the scheduler.
enum ConnMsg {
    /// A request line — or a reader-side parse failure, which still
    /// travels the queue so responses keep arrival order.
    Request(Result<Request, String>),
    /// A line the reader already rendered (the `hello` handshake reply,
    /// ISSUE 10). `hello` is handled ON the reader thread — the
    /// negotiated version must be bound before the next line is even
    /// parsed, so it can never race a command behind it — but its reply
    /// still travels the command queue so responses keep arrival order.
    Reply(String),
    /// The client hung up: drop its `watch` subscriptions so its writer
    /// thread (parked on the line queue) exits instead of leaking —
    /// the connection cap only tracks reader threads.
    Disconnected,
    /// A stepper worker finished a quantum (ISSUE 8): wake the blocked
    /// serve loop so it pumps the scheduler. Carries no payload — the
    /// outcome travels the scheduler's own completion channel; this is
    /// purely the wakeup, funneled through the command queue so the
    /// serve loop keeps a single blocking recv.
    Wake,
}

/// A connection message plus the connection's outbound line queue and
/// its protocol version at the moment the reader enqueued (versioned
/// per-message, not per-lookup: a `hello` upgrading the connection must
/// not retroactively re-shape replies to requests queued before it).
type Command = (ConnMsg, Sender<String>, Proto);

/// One `watch` subscription.
struct Watcher {
    tx: Sender<String>,
    every: u64,
    include_theta: bool,
    /// Iteration count at the last push (suppresses duplicate pushes
    /// when a quantum finishes a session without stepping it).
    last_iter: u64,
}

/// A bound serving endpoint. `bind` starts accepting connections;
/// [`Server::run`] consumes the server and processes them. All session
/// bookkeeping stays on the calling thread — stepper workers (if any)
/// only ever hold detached drivers mid-quantum.
pub struct Server {
    listener: TcpListener,
    rx: Receiver<Command>,
    sched: Scheduler,
    base_cfg: RunConfig,
    shutdown: Arc<AtomicBool>,
    /// session id → subscriptions (pruned at terminal push / dead client).
    watches: BTreeMap<u64, Vec<Watcher>>,
    /// Server-wide metrics registry (ISSUE 9): one live handle shared by
    /// the scheduler, every session/driver, the accept loop and the
    /// metrics listener. Answers the `stats` wire verb.
    obs: Registry,
    /// Where the Prometheus exposition is being served (None unless
    /// `serve.metrics_addr` was set).
    metrics_addr: Option<SocketAddr>,
}

impl Server {
    /// Bind `cfg.serve.addr` and start the accept thread. Submitted
    /// sessions start from `cfg` with the request's `config` overrides
    /// applied on top. With `cfg.serve.adopt` the ckpt_dir's manifest is
    /// adopted (sessions re-register as Paused under their original
    /// ids); without it, a ckpt_dir that already holds a manifest is
    /// refused.
    pub fn bind(cfg: &RunConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.serve.addr)
            .with_context(|| format!("binding serve.addr {:?}", cfg.serve.addr))?;
        std::fs::create_dir_all(&cfg.serve.ckpt_dir)
            .with_context(|| format!("creating serve.ckpt_dir {:?}", cfg.serve.ckpt_dir))?;
        let obs = Registry::new();
        let mut sched = Scheduler::new(
            cfg.serve.max_sessions,
            cfg.serve.policy,
            cfg.serve.ckpt_dir.clone(),
        );
        sched.set_obs(obs.clone());
        // per-quantum width arbitration over the server's physical pool
        sched.set_physical_pool(crate::runtime::NativePool::from_config(
            cfg.optex.threads,
            cfg.optex.pool,
        ));
        // scheduler-owned fault sites (manifest_fail) come from the
        // SERVER's fault spec; session-keyed sites fire from each
        // submission's own cfg.faults (inherited from this base config
        // unless the submit overrides it)
        sched.set_fault_plan(
            crate::faults::FaultPlan::parse(&cfg.faults)
                .context("parsing serve fault plan")?,
        );
        let mpath = manifest::manifest_path(&cfg.serve.ckpt_dir);
        if cfg.serve.adopt {
            if mpath.exists() {
                let n = sched.adopt_manifest()?;
                println!(
                    "serve: adopted {n} session(s) from {} (next id {})",
                    mpath.display(),
                    sched.next_id()
                );
            } else {
                println!("serve: --adopt with no manifest at {} (fresh start)", mpath.display());
            }
        } else if mpath.exists() {
            let (next_id, entries) = manifest::read(&mpath)
                .with_context(|| format!("inspecting {}", mpath.display()))?;
            bail!(
                "serve.ckpt_dir {:?} holds a session manifest from a previous \
                 server ({} adoptable session(s), id high-water {}): start with \
                 --adopt to adopt them, or point serve.ckpt_dir at a fresh \
                 directory (reusing it without adoption would hand out \
                 colliding session ids)",
                cfg.serve.ckpt_dir,
                entries.len(),
                next_id
            );
        }
        let (tx, rx) = mpsc::channel();
        if cfg.serve.steppers > 1 {
            // stepper-pool mode: workers wake the (possibly blocked)
            // serve loop through the command queue after each completed
            // quantum. The Mutex makes the captured Sender shareable
            // across workers; a Wake send is once per quantum, so the
            // lock is uncontended noise.
            let wake_tx = std::sync::Mutex::new(tx.clone());
            let dummy_reply = std::sync::Mutex::new(mpsc::channel::<String>().0);
            sched.set_steppers(
                cfg.serve.steppers,
                Some(Arc::new(move || {
                    if let (Ok(tx), Ok(reply)) = (wake_tx.lock(), dummy_reply.lock()) {
                        let _ = tx.send((ConnMsg::Wake, reply.clone(), Proto::V1));
                    }
                })),
            );
        }
        let shutdown = Arc::new(AtomicBool::new(false));
        {
            let listener = listener.try_clone()?;
            let shutdown = Arc::clone(&shutdown);
            let max_conns = cfg.serve.max_conns;
            let obs = obs.clone();
            std::thread::Builder::new()
                .name("optex-serve-accept".into())
                .spawn(move || accept_loop(listener, tx, shutdown, max_conns, obs))?;
        }
        // second listener: Prometheus text exposition, scraped without
        // touching the command queue (a slow scraper cannot stall a
        // quantum)
        let metrics_addr = if cfg.serve.metrics_addr.is_empty() {
            None
        } else {
            Some(expo::spawn_metrics_listener(&cfg.serve.metrics_addr, obs.clone())?)
        };
        Ok(Server {
            listener,
            rx,
            sched,
            base_cfg: cfg.clone(),
            shutdown,
            watches: BTreeMap::new(),
            obs,
            metrics_addr,
        })
    }

    /// Where the Prometheus exposition is served (`serve.metrics_addr`,
    /// with port 0 resolved), or None when metrics export is off.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a `shutdown` command (or every client handle is
    /// gone). Commands are drained before each scheduler pump; a pump is
    /// one inline quantum (serial) or a non-blocking reap-and-dispatch
    /// pass over the stepper pool (concurrent).
    pub fn run(mut self) -> Result<()> {
        loop {
            loop {
                match self.rx.try_recv() {
                    Ok(cmd) => {
                        if self.dispatch(cmd) {
                            return self.stop();
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => return self.stop(),
                }
            }
            let progressed = self.sched.pump();
            for id in &progressed {
                self.notify(*id);
            }
            if progressed.is_empty() {
                // Nothing completed and nothing further to dispatch, so
                // block. If quanta are in flight, a stepper worker's
                // Wake lands on this queue when one completes; if not,
                // nothing BECOMES runnable except through a command on
                // this queue (paused deadlines are only enforced when a
                // session next steps), so a blocking recv is both
                // correct and wakeup-free for an idle long-lived server.
                match self.rx.recv() {
                    Ok(cmd) => {
                        if self.dispatch(cmd) {
                            return self.stop();
                        }
                    }
                    Err(mpsc::RecvError) => return self.stop(),
                }
            }
        }
    }

    fn stop(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the accept thread so it observes the flag and exits
        if let Ok(addr) = self.listener.local_addr() {
            let _ = TcpStream::connect(addr);
        }
        Ok(())
    }

    /// Push the terminal record for `s` to its watchers and drop them.
    fn push_terminal(obs: &Registry, watches: &mut BTreeMap<u64, Vec<Watcher>>, s: &Session) {
        if let Some(ws) = watches.remove(&s.id()) {
            for w in ws {
                if w.tx.send(protocol::result_event_line(s, w.include_theta)).is_ok() {
                    obs.incr(Counter::WatchPushes);
                }
            }
        }
    }

    /// Streaming hook, called after the quantum that stepped session
    /// `id`: iter pushes on the subscriber's cadence, terminal push (and
    /// subscription teardown) when the session just finished.
    fn notify(&mut self, id: u64) {
        let obs = self.obs.clone();
        let Some(s) = self.sched.session(id) else { return };
        if let Some(ws) = self.watches.get_mut(&id) {
            let iters = s.iters_done();
            ws.retain_mut(|w| {
                if iters > w.last_iter && iters % w.every == 0 {
                    w.last_iter = iters;
                    // a vanished client prunes its subscription here
                    let sent = w.tx.send(protocol::iter_event_line(s)).is_ok();
                    if sent {
                        obs.incr(Counter::WatchPushes);
                    }
                    return sent;
                }
                true
            });
        }
        if !s.is_active() {
            Self::push_terminal(&obs, &mut self.watches, s);
        }
    }

    /// Terminal sweep for finishes that happen outside a quantum
    /// (client `cancel`, a failed `resume`): push + drop every
    /// subscription whose session is no longer active (or vanished).
    fn sweep_watches(&mut self) {
        let ids: Vec<u64> = self.watches.keys().copied().collect();
        for id in ids {
            match self.sched.session(id) {
                Some(s) if s.is_active() => {}
                Some(s) => Self::push_terminal(&self.obs, &mut self.watches, s),
                None => {
                    self.watches.remove(&id);
                }
            }
        }
    }

    /// Apply one command; returns true on shutdown. Replies are
    /// best-effort — a vanished client must not stall the scheduler.
    fn dispatch(&mut self, (msg, reply, proto): Command) -> bool {
        let req = match msg {
            ConnMsg::Request(Ok(r)) => r,
            ConnMsg::Request(Err(msg)) => {
                let _ = reply
                    .send(protocol::error_line_for(proto, ErrCode::BadRequest, &msg));
                return false;
            }
            ConnMsg::Reply(line) => {
                let _ = reply.send(line);
                return false;
            }
            ConnMsg::Disconnected => {
                // unsubscribe every watcher feeding this connection's
                // line queue; dropping the senders lets its writer
                // thread drain and exit
                for ws in self.watches.values_mut() {
                    ws.retain(|w| !w.tx.same_channel(&reply));
                }
                self.watches.retain(|_, ws| !ws.is_empty());
                return false;
            }
            // pure wakeup — the next loop iteration pumps the scheduler
            ConnMsg::Wake => return false,
        };
        let line = match req {
            Request::Shutdown => {
                let _ = reply.send(protocol::shutdown_line());
                return true;
            }
            // hello is handled on the reader thread (the version bind
            // must precede the next line's parse); this arm only fires
            // for a hand-built command in tests
            Request::Hello { .. } => protocol::hello_line(),
            Request::Submit { overrides, budget, paused } => {
                let mut cfg = self.base_cfg.clone();
                let applied: Result<(), _> =
                    overrides.iter().try_for_each(|kv| cfg.apply_override(kv));
                match applied {
                    Err(e) => protocol::error_line_for(
                        proto,
                        ErrCode::BadRequest,
                        &e.to_string(),
                    ),
                    Ok(()) => match self.sched.submit(cfg, budget) {
                        Ok(id) => {
                            if paused {
                                // suspend before the first quantum; if
                                // the suspend cannot be written the
                                // session must not linger runnable
                                // under an id the client never learned
                                // — cancel it and say which id died
                                if let Err(e) = self.sched.pause(id) {
                                    let _ = self.sched.cancel(id);
                                    protocol::error_line_for(
                                        proto,
                                        ErrCode::Internal,
                                        &format!(
                                            "session {id} admitted but paused \
                                             submission failed (session \
                                             cancelled): {e:#}"
                                        ),
                                    )
                                } else {
                                    protocol::submit_line(id, "paused")
                                }
                            } else {
                                protocol::submit_line(id, "pending")
                            }
                        }
                        Err(e) => coded_error(proto, &e, ErrCode::BadRequest),
                    },
                }
            }
            Request::Status { id: None } => {
                protocol::status_all_line(self.sched.sessions())
            }
            Request::Status { id: Some(id) } => match self.sched.session(id) {
                Some(s) => protocol::status_line(s),
                None => unknown_id(proto, id),
            },
            Request::Result { id, include_theta } => match self.sched.session(id) {
                Some(s) => protocol::result_line(s, include_theta),
                None => unknown_id(proto, id),
            },
            Request::Watch { id, stream_every, include_theta } => {
                let every =
                    stream_every.unwrap_or(self.base_cfg.serve.stream_every as u64);
                match self.sched.session(id) {
                    None => unknown_id(proto, id),
                    Some(s) if !s.is_active() => {
                        // finished already: ack, then the terminal push
                        // (ordered behind the ack on the same queue)
                        let _ = reply.send(protocol::watch_line(id, every));
                        let _ =
                            reply.send(protocol::result_event_line(s, include_theta));
                        return false;
                    }
                    Some(s) => {
                        self.watches.entry(id).or_default().push(Watcher {
                            tx: reply.clone(),
                            every,
                            include_theta,
                            last_iter: s.iters_done(),
                        });
                        protocol::watch_line(id, every)
                    }
                }
            }
            Request::Pause { id } => self.ack(proto, id, Scheduler::pause),
            Request::Resume { id } => self.ack(proto, id, Scheduler::resume),
            Request::Cancel { id } => self.ack(proto, id, Scheduler::cancel),
            Request::Export { id } => match self.sched.export(id) {
                Ok((entry, ckpt)) => {
                    let b64 = ckpt.map(|bytes| crate::util::b64::encode(&bytes));
                    protocol::export_line(&entry, b64.as_deref())
                }
                // default Internal: the remaining failure is checkpoint
                // I/O on a session that WAS exportable
                Err(e) => coded_error(proto, &e, ErrCode::Internal),
            },
            Request::Import { entry, ckpt } => {
                match self.sched.import(&entry, ckpt.as_deref()) {
                    Ok(id) => protocol::import_line(
                        self.sched.session(id).expect("import inserted id"),
                    ),
                    Err(e) => coded_error(proto, &e, ErrCode::Internal),
                }
            }
            // one grammar serves both tiers, but only `optex router`
            // has peers to move a session to
            Request::Migrate { .. } => protocol::error_line_for(
                proto,
                ErrCode::BadRequest,
                "migrate is a router verb (this is a single worker); \
                 connect to an optex router",
            ),
            Request::Stats => protocol::stats_line(&self.obs.snapshot()),
            Request::Trace { id } => match self.sched.session(id) {
                Some(s) => protocol::trace_line(s),
                None => unknown_id(proto, id),
            },
        };
        let _ = reply.send(line);
        // cancel / failed resume finish sessions without a quantum —
        // their watchers get the terminal push now, not never; an
        // export's watchers are dropped here too (their session left)
        self.sweep_watches();
        false
    }

    fn ack(
        &mut self,
        proto: Proto,
        id: u64,
        op: fn(&mut Scheduler, u64) -> Result<()>,
    ) -> String {
        match op(&mut self.sched, id) {
            Ok(()) => protocol::ack_line(self.sched.session(id).expect("op verified id")),
            // default BadState: a lifecycle verb on an id that exists
            // failed because the session cannot take it in its state
            Err(e) => coded_error(proto, &e, ErrCode::BadState),
        }
    }
}

/// `{"error":...,"ok":false}` for the session the request named but
/// this server does not hold.
fn unknown_id(proto: Proto, id: u64) -> String {
    protocol::error_line_for(proto, ErrCode::UnknownId, &format!("no such session {id}"))
}

/// Classify a scheduler error into its stable wire code by its
/// recognized failure class, falling back to the verb's `default`.
/// Matching on message text is the cost of `anyhow` errors — the
/// substrings below are produced by the scheduler itself and pinned by
/// its unit tests, so they cannot drift silently.
fn coded_error(proto: Proto, e: &anyhow::Error, default: ErrCode) -> String {
    let msg = format!("{e:#}");
    let code = if msg.contains("no such session") {
        ErrCode::UnknownId
    } else if msg.contains("at capacity") {
        ErrCode::Busy
    } else if msg.contains("not exportable") {
        ErrCode::BadState
    } else {
        default
    };
    protocol::error_line_for(proto, code, &msg)
}

fn accept_loop(
    listener: TcpListener,
    tx: Sender<Command>,
    shutdown: Arc<AtomicBool>,
    max_conns: usize,
    obs: Registry,
) {
    let conns = Arc::new(AtomicUsize::new(0));
    // Sheds used to be silent on the server side (the client got the
    // error line, the operator saw nothing). Count every one and say so
    // on stderr — rate-limited so an overload burst cannot turn the log
    // into the second casualty.
    let shed_log = Arc::new(BurstLog::new(std::time::Duration::from_secs(5)));
    let reject_log = Arc::new(BurstLog::new(std::time::Duration::from_secs(5)));
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = conn else { continue };
        // connection cap (`serve.max_conns`): each connection holds a
        // reader + writer thread; shed excess load at accept instead of
        // exhausting threads
        if conns.fetch_add(1, Ordering::SeqCst) >= max_conns {
            conns.fetch_sub(1, Ordering::SeqCst);
            obs.incr(Counter::ConnSheds);
            shed_log.note(&format!(
                "serve: shedding connection (serve.max_conns = {max_conns})"
            ));
            let mut s = stream;
            // pre-handshake by construction, so the v1 error shape
            // (Overloaded would be its v2 code, but no hello ran)
            let _ = s.write_all(
                protocol::error_line_for(
                    Proto::V1,
                    ErrCode::Overloaded,
                    "too many connections",
                )
                .as_bytes(),
            );
            let _ = s.write_all(b"\n");
            continue;
        }
        obs.gauge_set(Gauge::ConnsActive, conns.load(Ordering::SeqCst) as u64);
        let tx = tx.clone();
        let conns = Arc::clone(&conns);
        let conn_obs = obs.clone();
        let conn_reject_log = Arc::clone(&reject_log);
        let spawned = std::thread::Builder::new()
            .name("optex-serve-conn".into())
            .spawn(move || {
                handle_conn(stream, tx, &conn_obs, &conn_reject_log);
                let left = conns.fetch_sub(1, Ordering::SeqCst) - 1;
                conn_obs.gauge_set(Gauge::ConnsActive, left as u64);
            });
        if spawned.is_err() {
            conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Why [`read_line_capped`] gave up on a connection.
enum LineError {
    /// The line hit [`MAX_LINE_BYTES`] without a newline — the rest of
    /// it would be parsed as garbage requests, so the connection is
    /// beyond salvage.
    TooLong,
    /// Socket I/O error.
    Io,
}

/// Read one `\n`-terminated line of at most [`MAX_LINE_BYTES`]. Returns
/// `Ok(None)` on clean EOF.
fn read_line_capped(
    reader: &mut BufReader<TcpStream>,
) -> Result<Option<String>, LineError> {
    let mut line = String::new();
    let mut limited = (&mut *reader).take(MAX_LINE_BYTES);
    match limited.read_line(&mut line) {
        Ok(0) => Ok(None),
        Ok(n) => {
            if n as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
                Err(LineError::TooLong)
            } else {
                Ok(Some(line))
            }
        }
        Err(_) => Err(LineError::Io),
    }
}

/// Per-connection reader: parse request lines and forward them (parse
/// failures included, so response order is arrival order) to the
/// scheduler, paired with this connection's outbound line queue. The
/// paired writer thread owns the socket's write half and drains the
/// queue until every sender — the reader's clone AND any `watch`
/// registrations held by the scheduler — is gone.
fn handle_conn(
    stream: TcpStream,
    tx: Sender<Command>,
    obs: &Registry,
    reject_log: &BurstLog,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let (line_tx, line_rx) = mpsc::channel::<String>();
    let spawned = std::thread::Builder::new()
        .name("optex-serve-write".into())
        .spawn(move || {
            for line in line_rx {
                if writer
                    .write_all(line.as_bytes())
                    .and_then(|_| writer.write_all(b"\n"))
                    .and_then(|_| writer.flush())
                    .is_err()
                {
                    // dead socket: later sends into the queue error on
                    // the server side and prune any watch subscriptions
                    return;
                }
            }
        });
    if spawned.is_err() {
        return;
    }
    let mut reader = BufReader::new(read_half);
    // the connection's negotiated protocol version (ISSUE 10). Owned by
    // THIS thread and consulted between reads, so a `hello` strictly
    // orders before every line behind it — version upgrades cannot race
    // in-flight commands.
    let mut proto = Proto::default();
    loop {
        let line = match read_line_capped(&mut reader) {
            Ok(Some(line)) => line,
            Ok(None) => break,
            Err(LineError::TooLong) => {
                // previously this was only visible to the offending
                // client; count it and tell the operator too
                obs.incr(Counter::LineRejects);
                reject_log
                    .note("serve: rejected over-long request line (cap 1 MiB)");
                let _ = line_tx.send(protocol::error_line_for(
                    proto,
                    ErrCode::LineTooLong,
                    "request line too long",
                ));
                break;
            }
            Err(LineError::Io) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let parsed = protocol::parse_request(&line);
        if let Ok(Request::Hello { proto: requested }) = parsed {
            // handshake, handled here so the bind precedes the next
            // parse; the reply rides the command queue (ConnMsg::Reply)
            // to keep this connection's responses in arrival order
            let reply = match Proto::from_number(requested) {
                Some(p) => {
                    proto = p;
                    protocol::hello_line()
                }
                // the rejection is structured (v2 envelope) by design:
                // a client asking for v2+ understands it, and the
                // stable `version` code is what it retries on
                None => protocol::error_line_for(
                    Proto::V2,
                    ErrCode::Version,
                    &format!(
                        "unsupported protocol version {requested} (this server \
                         speaks 1..={})",
                        Proto::MAX
                    ),
                ),
            };
            if tx.send((ConnMsg::Reply(reply), line_tx.clone(), proto)).is_err() {
                let _ = line_tx.send(protocol::error_line_for(
                    proto,
                    ErrCode::ShuttingDown,
                    "server is shutting down",
                ));
                return;
            }
            continue;
        }
        let was_shutdown = matches!(parsed, Ok(Request::Shutdown));
        if tx.send((ConnMsg::Request(parsed), line_tx.clone(), proto)).is_err() {
            let _ = line_tx.send(protocol::error_line_for(
                proto,
                ErrCode::ShuttingDown,
                "server is shutting down",
            ));
            return;
        }
        if was_shutdown {
            // stop reading; the ack drains through the writer, which
            // exits once the server drops this connection's senders
            return;
        }
    }
    // client hung up: tell the scheduler so it drops this connection's
    // watch subscriptions (best-effort — on server shutdown the whole
    // watch table dies with it anyway)
    let _ = tx.send((ConnMsg::Disconnected, line_tx, proto));
}

/// `optex serve` entrypoint: bind, announce, run until shutdown.
pub fn serve(cfg: &RunConfig) -> Result<()> {
    let server = Server::bind(cfg)?;
    println!(
        "serve: listening on {} (max_sessions={}, policy={}, threads={}, pool={}, \
         steppers={})",
        server.local_addr()?,
        cfg.serve.max_sessions,
        cfg.serve.policy.name(),
        cfg.optex.threads,
        cfg.optex.pool.name(),
        cfg.serve.steppers,
    );
    if let Some(addr) = server.metrics_addr() {
        println!("serve: metrics exposition on http://{addr}/metrics");
    }
    server.run()
}
