//! The serve loop: a `TcpListener` accept thread feeding the scheduler
//! thread through an mpsc command queue.
//!
//! ## Threading model
//!
//! * **Scheduler thread** (the caller of [`Server::run`]) — owns the
//!   [`Scheduler`] and every session in it. All optimization work
//!   happens here, one session-iteration per quantum; within a quantum
//!   the iteration fans out over the shared native pool. Sessions are
//!   therefore free to hold non-`Send` state (the RL oracle does).
//! * **Accept thread** — blocks on `accept`, spawns one reader thread
//!   per connection. Woken for exit by a self-connect at shutdown.
//! * **Connection threads** — parse one JSONL request per line, ship
//!   `(Request, reply_tx)` to the scheduler, write the reply line back.
//!
//! The command queue is drained *before every scheduler quantum*, so
//! protocol latency is bounded by one session iteration, and command
//! application order is the arrival order — deterministic from a
//! client's point of view (its own commands are answered in order).
//!
//! Shutdown: the `shutdown` command is acknowledged, the queue stops
//! being served, and the accept thread is woken to exit. In-flight
//! sessions are dropped with the scheduler; sessions suspended at
//! shutdown leave their checkpoint files in `serve.ckpt_dir` for manual
//! inspection/recovery — cross-process adoption of those checkpoints is
//! a ROADMAP follow-up, not yet a protocol feature (and a new server
//! reuses session ids from 1, so point it at a fresh ckpt_dir).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::serve::protocol::{self, Request};
use crate::serve::scheduler::Scheduler;

/// Hard cap on one request line (a `submit` with a large config object
/// is well under 1 KiB; 1 MiB leaves room without letting a client
/// stream an endless newline-free line into server memory).
const MAX_LINE_BYTES: u64 = 1 << 20;

/// Cap on concurrently served connections (each costs one reader
/// thread). Excess connects are dropped at accept.
const MAX_CONNS: usize = 256;

type Command = (Request, Sender<String>);

/// A bound serving endpoint. `bind` starts accepting connections;
/// [`Server::run`] processes them (call it on the same thread — the
/// scheduler owns non-`Send` session state, which the compiler enforces).
pub struct Server {
    listener: TcpListener,
    rx: Receiver<Command>,
    sched: Scheduler,
    base_cfg: RunConfig,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind `cfg.serve.addr` and start the accept thread. Submitted
    /// sessions start from `cfg` with the request's `config` overrides
    /// applied on top.
    pub fn bind(cfg: &RunConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.serve.addr)
            .with_context(|| format!("binding serve.addr {:?}", cfg.serve.addr))?;
        std::fs::create_dir_all(&cfg.serve.ckpt_dir)
            .with_context(|| format!("creating serve.ckpt_dir {:?}", cfg.serve.ckpt_dir))?;
        let (tx, rx) = mpsc::channel();
        let shutdown = Arc::new(AtomicBool::new(false));
        {
            let listener = listener.try_clone()?;
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("optex-serve-accept".into())
                .spawn(move || accept_loop(listener, tx, shutdown))?;
        }
        let sched = Scheduler::new(
            cfg.serve.max_sessions,
            cfg.serve.policy,
            cfg.serve.ckpt_dir.clone(),
        );
        Ok(Server { listener, rx, sched, base_cfg: cfg.clone(), shutdown })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a `shutdown` command (or every client handle is
    /// gone). Commands are drained before each scheduler quantum.
    pub fn run(mut self) -> Result<()> {
        loop {
            loop {
                match self.rx.try_recv() {
                    Ok(cmd) => {
                        if self.dispatch(cmd) {
                            return self.stop();
                        }
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => return self.stop(),
                }
            }
            if self.sched.tick().is_none() {
                // Nothing runnable — and nothing BECOMES runnable except
                // through a command on this queue (paused deadlines are
                // only enforced when a session next steps), so a
                // blocking recv is both correct and wakeup-free for an
                // idle long-lived server.
                match self.rx.recv() {
                    Ok(cmd) => {
                        if self.dispatch(cmd) {
                            return self.stop();
                        }
                    }
                    Err(mpsc::RecvError) => return self.stop(),
                }
            }
        }
    }

    fn stop(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::SeqCst);
        // wake the accept thread so it observes the flag and exits
        if let Ok(addr) = self.listener.local_addr() {
            let _ = TcpStream::connect(addr);
        }
        Ok(())
    }

    /// Apply one command; returns true on shutdown. Replies are
    /// best-effort — a vanished client must not stall the scheduler.
    fn dispatch(&mut self, (req, reply): Command) -> bool {
        let line = match req {
            Request::Shutdown => {
                let _ = reply.send(protocol::shutdown_line());
                return true;
            }
            Request::Submit { overrides, budget } => {
                let mut cfg = self.base_cfg.clone();
                let applied: Result<(), _> =
                    overrides.iter().try_for_each(|kv| cfg.apply_override(kv));
                match applied {
                    Err(e) => protocol::error_line(&e.to_string()),
                    Ok(()) => match self.sched.submit(cfg, budget) {
                        Ok(id) => protocol::submit_line(id),
                        Err(e) => protocol::error_line(&format!("{e:#}")),
                    },
                }
            }
            Request::Status { id: None } => {
                protocol::status_all_line(self.sched.sessions())
            }
            Request::Status { id: Some(id) } => match self.sched.session(id) {
                Some(s) => protocol::status_line(s),
                None => protocol::error_line(&format!("no such session {id}")),
            },
            Request::Result { id, include_theta } => match self.sched.session(id) {
                Some(s) => protocol::result_line(s, include_theta),
                None => protocol::error_line(&format!("no such session {id}")),
            },
            Request::Pause { id } => self.ack(id, Scheduler::pause),
            Request::Resume { id } => self.ack(id, Scheduler::resume),
            Request::Cancel { id } => self.ack(id, Scheduler::cancel),
        };
        let _ = reply.send(line);
        false
    }

    fn ack(&mut self, id: u64, op: fn(&mut Scheduler, u64) -> Result<()>) -> String {
        match op(&mut self.sched, id) {
            Ok(()) => protocol::ack_line(self.sched.session(id).expect("op verified id")),
            Err(e) => protocol::error_line(&format!("{e:#}")),
        }
    }
}

fn accept_loop(listener: TcpListener, tx: Sender<Command>, shutdown: Arc<AtomicBool>) {
    let conns = Arc::new(AtomicUsize::new(0));
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = conn else { continue };
        // connection cap: each connection holds a reader thread; shed
        // excess load at accept instead of exhausting threads
        if conns.fetch_add(1, Ordering::SeqCst) >= MAX_CONNS {
            conns.fetch_sub(1, Ordering::SeqCst);
            let mut s = stream;
            let _ = s.write_all(protocol::error_line("too many connections").as_bytes());
            let _ = s.write_all(b"\n");
            continue;
        }
        let tx = tx.clone();
        let conns = Arc::clone(&conns);
        let spawned = std::thread::Builder::new()
            .name("optex-serve-conn".into())
            .spawn(move || {
                handle_conn(stream, tx);
                conns.fetch_sub(1, Ordering::SeqCst);
            });
        if spawned.is_err() {
            conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Read one `\n`-terminated line of at most [`MAX_LINE_BYTES`]. Returns
/// `Ok(None)` on clean EOF, `Err(())` on I/O error or an over-long line
/// (the connection is beyond salvage — the rest of the line would be
/// parsed as garbage requests).
fn read_line_capped(reader: &mut BufReader<TcpStream>) -> Result<Option<String>, ()> {
    let mut line = String::new();
    let mut limited = (&mut *reader).take(MAX_LINE_BYTES);
    match limited.read_line(&mut line) {
        Ok(0) => Ok(None),
        Ok(n) => {
            if n as u64 >= MAX_LINE_BYTES && !line.ends_with('\n') {
                Err(())
            } else {
                Ok(Some(line))
            }
        }
        Err(_) => Err(()),
    }
}

/// One JSONL request/response exchange per line until the client hangs
/// up (or the server shuts down mid-request).
fn handle_conn(stream: TcpStream, tx: Sender<Command>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let mut reader = BufReader::new(read_half);
    loop {
        let line = match read_line_capped(&mut reader) {
            Ok(Some(line)) => line,
            Ok(None) => return,
            Err(()) => {
                let _ = writer
                    .write_all(protocol::error_line("request line too long").as_bytes())
                    .and_then(|_| writer.write_all(b"\n"));
                return;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let mut was_shutdown = false;
        let reply = match protocol::parse_request(&line) {
            Err(e) => protocol::error_line(&e),
            Ok(req) => {
                was_shutdown = matches!(req, Request::Shutdown);
                let (rtx, rrx) = mpsc::channel();
                if tx.send((req, rtx)).is_err() {
                    protocol::error_line("server is shutting down")
                } else {
                    match rrx.recv() {
                        Ok(l) => l,
                        Err(_) => protocol::error_line("server is shutting down"),
                    }
                }
            }
        };
        if writer
            .write_all(reply.as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .is_err()
        {
            return;
        }
        if was_shutdown {
            return;
        }
    }
}

/// `optex serve` entrypoint: bind, announce, run until shutdown.
pub fn serve(cfg: &RunConfig) -> Result<()> {
    let server = Server::bind(cfg)?;
    println!(
        "serve: listening on {} (max_sessions={}, policy={}, threads={}, pool={})",
        server.local_addr()?,
        cfg.serve.max_sessions,
        cfg.serve.policy.name(),
        cfg.optex.threads,
        cfg.optex.pool.name(),
    );
    server.run()
}
