//! One serving session: a [`Driver`] wrapped with identity, lifecycle
//! state, a budget, and checkpoint-backed suspend/resume.
//!
//! A session is the serving subsystem's unit of work (a *run* was the
//! binary's). It owns everything the driver owns — oracle, optimizer,
//! `GradStore` arena, RNG streams (forked from `cfg.seed` at build) — so
//! K concurrent sessions of dimension d hold K·T₀·d gradient floats
//! total and nothing is shared between sessions except the compute
//! substrate. That isolation is what makes the scheduler's determinism
//! argument trivial: stepping order across sessions cannot influence any
//! session's numerics (see `scheduler.rs`).
//!
//! ## Lifecycle
//!
//! ```text
//! Pending ──step──▶ Running ──budget/cancel/error──▶ Done | Failed
//!    ▲                │ ▲
//!    └───── (admit)   │ └──resume──┐
//!                   pause ──▶ Paused
//! ```
//!
//! `pause` on a factory-built session is a checkpoint-backed *suspend*:
//! the run is streamed to disk via the existing `checkpoint` module and
//! the driver (arena included) is dropped, so paused sessions cost a
//! file, not T₀·d floats of RAM. `resume` rebuilds the driver from the
//! session's config and restores it with [`Driver::resume_from`] — for
//! deterministic workloads the continued trajectory is bit-identical to
//! an unpaused run (the standard checkpoint caveat applies to stochastic
//! oracles: their data-sampler RNG restarts from the config seed).
//! Sessions built around an injected oracle (tests, RL) cannot be
//! rebuilt, so their pause keeps the driver in memory.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::metrics::IterRecord;
use crate::coordinator::Driver;
use crate::obs::{Counter, FlightRecorder, Hist, ObsEvent, Registry, TracePhase};
use crate::runtime::NativePool;
use crate::serve::manifest;
use crate::workloads::{factory, GradSource};

/// EMA smoothing for the per-session eval-seconds estimate feeding the
/// weighted-fair scheduler (~"last 10 iterations" horizon).
const EVAL_EMA_ALPHA: f64 = 0.2;

/// Session lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionState {
    /// Admitted, not yet stepped.
    Pending,
    /// Being stepped by the scheduler.
    Running,
    /// Suspended (checkpoint on disk for rebuildable sessions).
    Paused,
    /// Budget exhausted or target reached; result available.
    Done,
    /// Driver error or client cancel; `error()` has the reason.
    Failed,
}

impl SessionState {
    pub fn name(&self) -> &'static str {
        match self {
            SessionState::Pending => "pending",
            SessionState::Running => "running",
            SessionState::Paused => "paused",
            SessionState::Done => "done",
            SessionState::Failed => "failed",
        }
    }
}

/// Per-session stopping budget. Every bound is optional; `max_iters`
/// defaults to the config's `steps`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Budget {
    /// Hard cap on sequential iterations (None → `cfg.steps`).
    pub max_iters: Option<u64>,
    /// Stop as soon as the best loss reaches this value.
    pub target_loss: Option<f64>,
    /// Wall-clock deadline in seconds since submission. Checked before
    /// each step of a runnable session (a paused session's clock keeps
    /// ticking but is only enforced once it runs again).
    pub deadline_s: Option<f64>,
}

impl Budget {
    fn effective_max(&self, cfg_steps: usize) -> u64 {
        self.max_iters.unwrap_or(cfg_steps as u64)
    }
}

/// A [`Driver`] under serving management. See the module docs for the
/// lifecycle; construction is via [`Session::build`] (config → factory
/// workload, the protocol path) or [`Session::with_source`] (injected
/// oracle — tests, benches, RL).
pub struct Session {
    id: u64,
    cfg: RunConfig,
    budget: Budget,
    state: SessionState,
    /// None once finished or suspended-to-disk (the arena is freed).
    driver: Option<Driver>,
    /// Factory-built sessions can be rebuilt from `cfg` after a suspend;
    /// injected-oracle sessions cannot (their pause keeps the driver).
    rebuildable: bool,
    ckpt_path: Option<PathBuf>,
    iters_done: u64,
    /// Metric rows carried across suspend cycles and capture-at-finish
    /// (the driver's record dies with the driver).
    archived_rows: Vec<IterRecord>,
    archived_best: f64,
    stop_reason: Option<&'static str>,
    error: Option<String>,
    final_theta: Option<Vec<f32>>,
    /// `(store_allocs, grad_bytes_copied)` captured when the driver is
    /// released — the steady-state zero-alloc/zero-copy evidence for the
    /// serve bench (ISSUE 4 acceptance).
    counters: Option<(u64, u64)>,
    /// Robustness counters carried across suspend cycles (the live
    /// driver's part dies with it — ISSUE 7).
    archived_retries: u64,
    archived_nonfinite: u64,
    /// True when the session was Failed by catching a panicking oracle
    /// (the `catch_unwind` quarantine boundary in [`Quantum::run`]).
    quarantined: bool,
    submitted_at: Instant,
    eval_ema_s: f64,
    /// Weighted-fair virtual time: Σ of the EMA at each step taken.
    vtime: f64,
    /// Width the arbiter granted for the most recent quantum (None until
    /// a granted step runs — observability for the arbitration tests).
    last_grant: Option<usize>,
    /// Metrics registry handle (ISSUE 9); disabled until the scheduler
    /// installs the server-wide one at admission.
    obs: Registry,
    /// Flight recorder: this session's bounded ring of lifecycle and
    /// driver events (rendered by the `trace` verb, dumped to disk at a
    /// Failed finish). Sequence numbers are assigned at push on the
    /// serve thread — a single totally-ordered log per session.
    recorder: FlightRecorder,
    /// When the session last became runnable (admit / step complete /
    /// resume) — the queue-wait histogram's start mark. Metrics only:
    /// never enters records or renders.
    runnable_since: Option<Instant>,
}

impl Session {
    /// Build from config via the workload factory (the protocol path).
    /// `ckpt_dir` hosts this session's suspend file.
    pub fn build(id: u64, cfg: RunConfig, budget: Budget, ckpt_dir: &Path) -> Result<Session> {
        let workload = factory::build(&cfg)?;
        let mut driver = Driver::new(cfg.clone(), workload)?;
        driver.set_session_id(id);
        Ok(Session::assemble(
            id,
            cfg,
            budget,
            Some(driver),
            true,
            Some(ckpt_dir.join(format!("session_{id}.ckpt"))),
        ))
    }

    /// Build around an injected oracle (tests, benches, the RL stack).
    /// Not rebuildable: pause keeps the driver in memory.
    pub fn with_source(
        id: u64,
        cfg: RunConfig,
        source: Box<dyn GradSource>,
        budget: Budget,
    ) -> Result<Session> {
        let mut driver = Driver::with_source(cfg.clone(), source, None)?;
        driver.set_session_id(id);
        Ok(Session::assemble(id, cfg, budget, Some(driver), false, None))
    }

    fn assemble(
        id: u64,
        cfg: RunConfig,
        budget: Budget,
        driver: Option<Driver>,
        rebuildable: bool,
        ckpt_path: Option<PathBuf>,
    ) -> Session {
        let mut session = Session {
            id,
            cfg,
            budget,
            state: SessionState::Pending,
            driver,
            rebuildable,
            ckpt_path,
            iters_done: 0,
            archived_rows: Vec::new(),
            archived_best: f64::INFINITY,
            stop_reason: None,
            error: None,
            final_theta: None,
            counters: None,
            archived_retries: 0,
            archived_nonfinite: 0,
            quarantined: false,
            submitted_at: Instant::now(),
            eval_ema_s: 0.0,
            vtime: 0.0,
            last_grant: None,
            obs: Registry::disabled(),
            recorder: FlightRecorder::new(),
            runnable_since: Some(Instant::now()),
        };
        session.recorder.push(ObsEvent::new(TracePhase::Submit, 0, ""));
        session
    }

    /// Re-register a session from a restart-adoption manifest entry
    /// (ISSUE 5): Paused, driver-less, rebuildable. With `iters > 0` the
    /// suspend checkpoint at the session's canonical path must exist —
    /// `resume` restores it bit-identically; with `iters == 0` (the
    /// session was running, never suspended) `resume` rebuilds from
    /// config and re-runs from its seed — unless a suspend checkpoint
    /// turns out to exist anyway (kill between checkpoint write and
    /// manifest rewrite), in which case `resume` restores it and adopts
    /// its iteration count. The deadline clock (if any) restarts at
    /// adoption.
    pub fn adopt(
        id: u64,
        cfg: RunConfig,
        budget: Budget,
        ckpt_dir: &Path,
        iters_done: u64,
    ) -> Session {
        let ckpt_path = Some(ckpt_dir.join(format!("session_{id}.ckpt")));
        let mut session = Session::assemble(id, cfg, budget, None, true, ckpt_path);
        session.state = SessionState::Paused;
        session.iters_done = iters_done;
        session.runnable_since = None;
        session
    }

    // -- accessors -----------------------------------------------------------

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn state(&self) -> SessionState {
        self.state
    }

    /// Eligible for a scheduler step.
    pub fn is_runnable(&self) -> bool {
        matches!(self.state, SessionState::Pending | SessionState::Running)
    }

    /// Holds admission capacity (not yet finished).
    pub fn is_active(&self) -> bool {
        !matches!(self.state, SessionState::Done | SessionState::Failed)
    }

    /// Paused with the driver released to a checkpoint file.
    pub fn is_suspended(&self) -> bool {
        self.state == SessionState::Paused && self.driver.is_none()
    }

    pub fn iters_done(&self) -> u64 {
        self.iters_done
    }

    pub fn workload(&self) -> &str {
        &self.cfg.workload
    }

    pub fn method(&self) -> &'static str {
        self.cfg.method.name()
    }

    pub fn stop_reason(&self) -> Option<&'static str> {
        self.stop_reason
    }

    pub fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }

    /// Best loss across the whole session (archived + live driver).
    pub fn best_loss(&self) -> f64 {
        let live = self.driver.as_ref().map(|d| d.best_loss()).unwrap_or(f64::INFINITY);
        self.archived_best.min(live)
    }

    /// All metric rows so far, suspend cycles included, in order.
    pub fn rows(&self) -> Vec<IterRecord> {
        let mut rows = self.archived_rows.clone();
        if let Some(d) = &self.driver {
            rows.extend(d.record().rows.iter().cloned());
        }
        rows
    }

    /// Loss of the most recent logged iteration.
    pub fn last_loss(&self) -> Option<f64> {
        if let Some(d) = &self.driver {
            if let Some(r) = d.record().rows.last() {
                return Some(r.loss);
            }
        }
        self.archived_rows.last().map(|r| r.loss)
    }

    /// Current (live) or final (finished) iterate. None only while
    /// suspended — the iterate lives in the checkpoint file.
    pub fn theta(&self) -> Option<Vec<f32>> {
        if let Some(d) = &self.driver {
            return Some(d.theta().to_vec());
        }
        self.final_theta.clone()
    }

    /// `(store_allocs, grad_bytes_copied)` of the session's arena — live
    /// from the driver, or as captured when it was released.
    pub fn grad_counters(&self) -> Option<(u64, u64)> {
        if let Some(d) = &self.driver {
            return Some((d.history().store_allocs(), d.history().grad_bytes_copied()));
        }
        self.counters
    }

    /// Eval fan-out retries across the whole session (archived + live
    /// driver — survives suspend cycles).
    pub fn retries(&self) -> u64 {
        self.archived_retries + self.driver.as_ref().map(|d| d.retries()).unwrap_or(0)
    }

    /// Non-finite eval points absorbed by `optex.on_nonfinite` across
    /// the whole session.
    pub fn nonfinite(&self) -> u64 {
        self.archived_nonfinite
            + self.driver.as_ref().map(|d| d.nonfinite_events()).unwrap_or(0)
    }

    /// True when this session went Failed by quarantining a panicking
    /// oracle (as opposed to a clean `Err` or a client cancel).
    pub fn quarantined(&self) -> bool {
        self.quarantined
    }

    /// Install the server-wide metrics registry (ISSUE 9): the session
    /// keeps a handle for its own histograms and passes a clone to the
    /// live driver (and to every driver rebuilt on resume).
    pub(crate) fn set_obs(&mut self, obs: Registry) {
        if let Some(d) = self.driver.as_mut() {
            d.set_obs(obs.clone());
        }
        self.obs = obs;
    }

    /// Append one event to this session's flight-recorder ring.
    pub(crate) fn record_event(
        &mut self,
        phase: TracePhase,
        iter: u64,
        detail: impl Into<String>,
    ) {
        self.recorder.push(ObsEvent::new(phase, iter, detail));
    }

    /// The rendered flight-recorder ring, oldest first (the `trace`
    /// verb and the Failed-session status dump).
    pub fn trace_lines(&self) -> Vec<String> {
        self.recorder.render()
    }

    /// Events recorded over this session's lifetime (≥ the ring length
    /// — old events fall off the bounded ring).
    pub fn trace_total(&self) -> u64 {
        self.recorder.total_recorded()
    }

    /// Smoothed measured eval-seconds per iteration (weighted-fair key).
    pub fn eval_ema_s(&self) -> f64 {
        self.eval_ema_s
    }

    /// Accumulated weighted-fair virtual time.
    pub fn vtime(&self) -> f64 {
        self.vtime
    }

    /// Scheduler hook: floor the virtual time on admission/re-entry
    /// (standard WFQ — a newcomer competes from the incumbents' minimum,
    /// it does not monopolize the pool "catching up" from zero).
    pub(crate) fn set_vtime(&mut self, v: f64) {
        self.vtime = v;
    }

    pub fn submitted_at(&self) -> Instant {
        self.submitted_at
    }

    /// The session's requested pool width (`optex.threads` at submit;
    /// 0 = defer to the server's physical budget).
    pub fn requested_threads(&self) -> usize {
        self.cfg.optex.threads
    }

    /// Width of the most recent arbiter grant (None before the first
    /// granted quantum, or when the scheduler runs without an arbiter).
    pub fn granted_threads(&self) -> Option<usize> {
        self.last_grant
    }

    /// Install the arbiter's per-quantum pool grant on the live driver
    /// (no-op while suspended — `resume` rebuilds the driver and the
    /// next granted quantum re-applies). Bit-identity is unaffected at
    /// any width (`thread_invariance.rs`), so grants may vary freely
    /// between quanta.
    pub(crate) fn apply_pool(&mut self, pool: NativePool) {
        if let Some(d) = self.driver.as_mut() {
            d.set_compute_pool(pool);
            self.last_grant = Some(pool.threads());
            let detail = format!(
                "width={} requested={}",
                pool.threads(),
                self.cfg.optex.threads
            );
            self.record_event(TracePhase::Grant, self.iters_done + 1, detail);
        }
    }

    /// This session's line in the durable manifest: present only for
    /// factory-rebuildable, still-active sessions (injected-oracle
    /// sessions cannot be rebuilt on another server; finished ones have
    /// nothing to adopt). None also if the config contains strings the
    /// override grammar cannot encode (control characters).
    pub(crate) fn manifest_entry(&self) -> Option<manifest::Entry> {
        if !self.rebuildable || !self.is_active() {
            return None;
        }
        let overrides = self.cfg.overrides_from_default().ok()?;
        let ckpt = if self.is_suspended() {
            self.ckpt_path
                .as_ref()
                .and_then(|p| p.file_name())
                .map(|f| f.to_string_lossy().into_owned())
        } else {
            None
        };
        Some(manifest::Entry {
            id: self.id,
            state: self.state.name().to_string(),
            iters: self.iters_done,
            ckpt,
            budget: self.budget.clone(),
            overrides,
        })
    }

    // -- lifecycle -----------------------------------------------------------

    /// Run exactly ONE sequential iteration (the scheduler's quantum) and
    /// apply budget checks. No-op unless runnable. Driver errors mark the
    /// session Failed (never propagate — one session's oracle blowing up
    /// must not take the serve loop down).
    ///
    /// This is the inline composition of the three-phase quantum protocol
    /// ([`Session::begin_quantum`] → [`Quantum::run`] →
    /// [`Session::complete_quantum`]) that the concurrent stepper pool
    /// (ISSUE 8) drives across threads — the serial path and the
    /// dispatched path share every line of lifecycle logic by
    /// construction.
    pub fn step(&mut self) {
        if let BeginOutcome::Started(q) = self.begin_quantum() {
            let outcome = q.run();
            self.complete_quantum(outcome);
        }
    }

    /// Phase 1 (serve thread): apply the pre-step budget gates and, if
    /// the session should run, detach the driver into a [`Quantum`] ready
    /// to execute on any thread. While the quantum is in flight the
    /// session stays `Running` with `driver: None`; the scheduler's
    /// in-flight set is what prevents a second dispatch (the accessors
    /// all degrade to the archived view, so `status` queries during an
    /// in-flight quantum stay safe).
    pub(crate) fn begin_quantum(&mut self) -> BeginOutcome {
        if !self.is_runnable() {
            return BeginOutcome::NotRunnable;
        }
        if let Some(dl) = self.budget.deadline_s {
            if self.submitted_at.elapsed().as_secs_f64() >= dl {
                self.finish(SessionState::Done, Some("deadline"), None);
                return BeginOutcome::Finished;
            }
        }
        // iteration-count budget gates BEFORE the step (a max_iters: 0
        // submission must not run a fan-out); target_loss stays
        // post-step — it needs at least one observation to be
        // meaningful (best_loss is +inf until then).
        if self.iters_done >= self.budget.effective_max(self.cfg.steps) {
            self.finish(SessionState::Done, Some("max_iters"), None);
            return BeginOutcome::Finished;
        }
        self.state = SessionState::Running;
        let t = (self.iters_done + 1) as usize;
        if let Some(since) = self.runnable_since.take() {
            self.obs.observe(Hist::QueueWaitUs, since.elapsed().as_micros() as u64);
        }
        self.record_event(TracePhase::BeginQuantum, t as u64, "");
        let driver = self.driver.take().expect("runnable session has a driver");
        BeginOutcome::Started(Quantum {
            session_id: self.id,
            t,
            driver: Some(driver),
            dispatched: Instant::now(),
        })
    }

    /// Phase 3 (serve thread): reattach the driver (or quarantine the
    /// session if the quantum panicked), charge the weighted-fair clock
    /// from the WORKER-measured step seconds, and apply the post-step
    /// budget checks. The EMA deliberately uses the time measured around
    /// `Driver::iteration` on the executing thread — never serve-thread
    /// wall-clock — so co-scheduled peers' quanta cannot inflate each
    /// other's fair-share cost (ISSUE 8 satellite).
    pub(crate) fn complete_quantum(&mut self, outcome: QuantumOutcome) {
        match outcome {
            QuantumOutcome::Panicked { mut driver, message, dispatched, .. } => {
                // Failure-domain boundary (ISSUE 7): the panic payload
                // stopped at the `catch_unwind` in `Quantum::run`. The
                // session goes Failed with the message queryable via
                // `status`; reattaching the driver first lets `finish`
                // archive its pre-panic rows and then drop it (arena
                // and any outstanding loan included). The other K−1
                // sessions never observe any of it.
                self.obs
                    .observe(Hist::QuantumLatencyUs, dispatched.elapsed().as_micros() as u64);
                // the driver's in-quantum events (the fired fault) ride
                // back with it — drain them BEFORE the quarantine marker
                // so the trace reads in causal order
                for e in driver.take_events() {
                    self.recorder.push(e);
                }
                self.obs.incr(Counter::SessionsQuarantined);
                self.quarantined = true;
                self.driver = Some(driver);
                self.record_event(
                    TracePhase::Quarantine,
                    self.iters_done + 1,
                    message.clone(),
                );
                self.finish(
                    SessionState::Failed,
                    Some("quarantined"),
                    Some(format!("panic in Driver::iteration: {message}")),
                );
            }
            QuantumOutcome::Ran { mut driver, result, step_eval_s, dispatched, .. } => {
                self.obs
                    .observe(Hist::QuantumLatencyUs, dispatched.elapsed().as_micros() as u64);
                for e in driver.take_events() {
                    self.recorder.push(e);
                }
                self.driver = Some(driver);
                if let Err(e) = result {
                    self.finish(SessionState::Failed, Some("error"), Some(format!("{e:#}")));
                    return;
                }
                self.iters_done += 1;
                self.eval_ema_s = if self.iters_done == 1 {
                    step_eval_s
                } else {
                    EVAL_EMA_ALPHA * step_eval_s
                        + (1.0 - EVAL_EMA_ALPHA) * self.eval_ema_s
                };
                self.vtime += self.eval_ema_s;

                if self.iters_done >= self.budget.effective_max(self.cfg.steps) {
                    self.finish(SessionState::Done, Some("max_iters"), None);
                } else if let Some(target) = self.budget.target_loss {
                    if self.best_loss() <= target {
                        self.finish(SessionState::Done, Some("target_loss"), None);
                    }
                }
                if self.is_runnable() {
                    self.runnable_since = Some(Instant::now());
                }
            }
        }
    }

    /// Archive the driver's metrics/best-loss and release it (used at
    /// finish and at suspend — the record dies with the driver).
    fn archive_driver(&mut self) -> Option<Driver> {
        let drv = self.driver.take()?;
        self.archived_best = self.archived_best.min(drv.best_loss());
        self.archived_rows.extend(drv.record().rows.iter().cloned());
        self.archived_retries += drv.retries();
        self.archived_nonfinite += drv.nonfinite_events();
        self.counters =
            Some((drv.history().store_allocs(), drv.history().grad_bytes_copied()));
        Some(drv)
    }

    fn finish(
        &mut self,
        state: SessionState,
        stop_reason: Option<&'static str>,
        error: Option<String>,
    ) {
        if let Some(drv) = self.archive_driver() {
            self.final_theta = Some(drv.theta().to_vec());
            // drv dropped here: the session's arena is freed — K done
            // sessions cost K·d floats (their thetas), not K·T₀·d.
        }
        // a terminal session's suspend file is dead weight — a
        // long-lived server must not accrete stale checkpoints
        if let Some(p) = &self.ckpt_path {
            let _ = std::fs::remove_file(p);
        }
        self.state = state;
        self.stop_reason = stop_reason;
        self.error = error;
        self.runnable_since = None;
        let detail = match (stop_reason, &self.error) {
            (Some(r), _) => r.to_string(),
            (None, Some(e)) => e.clone(),
            (None, None) => String::new(),
        };
        self.record_event(TracePhase::Finish, self.iters_done, detail);
        if state == SessionState::Failed {
            // a dead session carries its own post-mortem: drop the
            // rendered ring next to the checkpoints. Best-effort — a
            // full disk must not take the serve loop down.
            if let Some(dir) = self.ckpt_path.as_ref().and_then(|p| p.parent()) {
                let _ = self.recorder.dump(&dir.join(format!("trace_{}.txt", self.id)));
            }
        }
    }

    /// Pause. Rebuildable sessions suspend: the run streams to the
    /// checkpoint file and the driver (arena included) is dropped.
    pub fn pause(&mut self) -> Result<()> {
        if !self.is_runnable() {
            bail!("session {} is {}, cannot pause", self.id, self.state.name());
        }
        if self.rebuildable {
            let path = self.ckpt_path.clone().expect("rebuildable session has a path");
            self.driver
                .as_ref()
                .expect("runnable session has a driver")
                .save_checkpoint(&path, self.iters_done)?;
            self.archive_driver();
        }
        self.state = SessionState::Paused;
        self.runnable_since = None;
        self.record_event(TracePhase::Pause, self.iters_done, "");
        Ok(())
    }

    /// Resume a paused session; suspended (or adopted) ones rebuild
    /// their driver from config and restore from the suspend checkpoint
    /// when one exists.
    ///
    /// A resume of a *non*-paused session is a transition error: the
    /// state is untouched. A resume whose driver rebuild or checkpoint
    /// restore fails (truncated file, missing file for a session with
    /// progress, shape mismatch) marks the session **Failed** — the
    /// driver is unrecoverable, and leaving it Paused would invite
    /// clients to retry forever against a dead checkpoint. The error is
    /// returned either way; the serve loop stays up (ISSUE 5 satellite).
    pub fn resume(&mut self) -> Result<()> {
        if self.state != SessionState::Paused {
            bail!("session {} is {}, cannot resume", self.id, self.state.name());
        }
        if self.driver.is_none() {
            match self.rebuild_driver() {
                Ok(mut drv) => {
                    drv.set_obs(self.obs.clone());
                    self.driver = Some(drv);
                }
                Err(e) => {
                    let msg = format!("session {}: resume failed: {e:#}", self.id);
                    self.finish(SessionState::Failed, Some("error"), Some(msg.clone()));
                    bail!("{msg}");
                }
            }
        }
        self.state = SessionState::Running;
        self.runnable_since = Some(Instant::now());
        self.record_event(TracePhase::Resume, self.iters_done, "");
        Ok(())
    }

    /// Rebuild the driver from config; restore the suspend checkpoint
    /// when present (required whenever the session has recorded
    /// progress).
    ///
    /// The suspend file is deliberately NOT deleted on a successful
    /// restore: a kill after the restore but before the scheduler's
    /// manifest rewrite would otherwise leave a manifest that promises a
    /// checkpoint no longer on disk (the reverse of the write-side crash
    /// window below) — permanently failing the session at adoption. The
    /// file stays until the next `pause` overwrites it or `finish`
    /// deletes it; while the session runs it is merely stale, and if the
    /// server dies mid-run the stray-checkpoint branch below turns it
    /// into a better recovery point than the seed re-run.
    fn rebuild_driver(&mut self) -> Result<Driver> {
        let path = self.ckpt_path.clone().expect("rebuildable session has a path");
        let build = |cfg: &RunConfig, id: u64| -> Result<Driver> {
            let workload = factory::build(cfg)?;
            let mut drv = Driver::new(cfg.clone(), workload)?;
            drv.set_session_id(id);
            Ok(drv)
        };
        if path.exists() {
            if self.iters_done == 0 {
                // Bookkeeping says "no progress" yet a suspend file
                // exists: a kill landed between a checkpoint write and
                // the manifest rewrite (the exact crash window adoption
                // exists for). The file is newer truth than the manifest
                // when it restores cleanly — adopt its iteration count;
                // a torn write falls back to the seed re-run instead of
                // permanently failing an otherwise-healthy session.
                let mut drv = build(&self.cfg, self.id)?;
                match drv.resume_from(&path) {
                    Ok(it) => {
                        self.iters_done = it;
                        return Ok(drv);
                    }
                    Err(_) => {
                        // partial restore may have touched driver state:
                        // discard it and build fresh from the seed (and
                        // drop the torn file — it can never restore)
                        let _ = std::fs::remove_file(path);
                        return build(&self.cfg, self.id);
                    }
                }
            }
            let mut drv = build(&self.cfg, self.id)?;
            let it = drv.resume_from(&path)?;
            if it != self.iters_done {
                bail!(
                    "suspend checkpoint is at iteration {it}, \
                     session bookkeeping says {}",
                    self.iters_done
                );
            }
            Ok(drv)
        } else if self.iters_done > 0 {
            bail!(
                "suspend checkpoint {} is missing (session has {} iterations \
                 of progress)",
                path.display(),
                self.iters_done
            );
        } else {
            // no checkpoint + no progress: an adopted never-suspended
            // session re-runs from its seed
            build(&self.cfg, self.id)
        }
    }

    /// Client cancel: a terminal Failed with a canonical reason. Errors
    /// if the session already finished.
    pub fn cancel(&mut self) -> Result<()> {
        if !self.is_active() {
            bail!("session {} already {}", self.id, self.state.name());
        }
        self.finish(SessionState::Failed, Some("cancelled"), Some("cancelled by client".into()));
        Ok(())
    }
}

/// What [`Session::begin_quantum`] decided.
pub(crate) enum BeginOutcome {
    /// Driver detached: run the quantum (any thread) and hand its
    /// [`QuantumOutcome`] back to [`Session::complete_quantum`].
    Started(Quantum),
    /// A pre-step budget gate fired (deadline / max_iters): the session
    /// finished without running an iteration.
    Finished,
    /// Not runnable (paused or terminal) — nothing to do.
    NotRunnable,
}

/// A detached in-flight quantum: the session's driver plus the iteration
/// number it must run. `Send` by construction (asserted below) — this is
/// the unit the stepper pool moves between threads. Exactly one thread
/// touches the driver at a time; *which* thread changes between quanta.
pub(crate) struct Quantum {
    session_id: u64,
    t: usize,
    /// `Option` so the `catch_unwind` closure can borrow it mutably and
    /// the Ok-path can still move it out afterwards.
    driver: Option<Driver>,
    /// When the serve thread detached the quantum — start mark of the
    /// whole-quantum latency histogram (metrics only, never records).
    dispatched: Instant,
}

impl Quantum {
    pub(crate) fn session_id(&self) -> u64 {
        self.session_id
    }

    /// Phase 2 (any thread): run the one iteration under `catch_unwind`,
    /// timing it on THIS thread. The worker-measured seconds are the only
    /// timing the fair-share EMA ever sees (see
    /// [`Session::complete_quantum`]).
    ///
    /// A panicking oracle is quarantined HERE — whether it fired on the
    /// executing thread or was re-raised out of either pool mode, the
    /// payload stops at this frame. The driver survives the catch and
    /// rides back in the outcome so `complete_quantum` can archive its
    /// pre-panic metric rows before dropping it — exactly what the
    /// serial path always did. `AssertUnwindSafe` is justified by that
    /// archive-then-drop: the possibly-inconsistent driver is only ever
    /// read for metrics, never stepped again. A worker always produces
    /// an outcome, so the scheduler can never leak a grant.
    pub(crate) fn run(mut self) -> QuantumOutcome {
        let t = self.t;
        let mut driver = self.driver.take().expect("quantum holds the driver");
        let start = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            driver.iteration(t)
        }));
        let step_eval_s = start.elapsed().as_secs_f64();
        match result {
            Ok(result) => QuantumOutcome::Ran {
                session_id: self.session_id,
                driver,
                result,
                step_eval_s,
                dispatched: self.dispatched,
            },
            Err(payload) => QuantumOutcome::Panicked {
                session_id: self.session_id,
                driver,
                message: panic_message(payload.as_ref()),
                dispatched: self.dispatched,
            },
        }
    }
}

/// What a quantum produced, to be reattached by
/// [`Session::complete_quantum`] on the serve thread.
pub(crate) enum QuantumOutcome {
    /// The iteration ran (successfully or to a clean `Err`); the driver
    /// comes back with it. `step_eval_s` is the wall time measured on
    /// the executing thread around `Driver::iteration` only.
    Ran {
        session_id: u64,
        driver: Driver,
        result: Result<()>,
        step_eval_s: f64,
        /// Serve-thread dispatch mark, for the quantum-latency histogram.
        dispatched: Instant,
    },
    /// The iteration panicked; the driver comes back only so its
    /// pre-panic metrics can be archived — it is never stepped again.
    Panicked {
        session_id: u64,
        driver: Driver,
        message: String,
        /// Serve-thread dispatch mark, for the quantum-latency histogram.
        dispatched: Instant,
    },
}

impl QuantumOutcome {
    pub(crate) fn session_id(&self) -> u64 {
        match self {
            QuantumOutcome::Ran { session_id, .. } => *session_id,
            QuantumOutcome::Panicked { session_id, .. } => *session_id,
        }
    }
}

// Compile-time proof that quanta (driver, oracle, optimizer, arena and
// all) may be handed to stepper-pool workers. If an oracle grows
// non-`Send` state this fails the BUILD, not the dispatch path at
// runtime.
#[allow(dead_code)]
fn _quanta_are_send() {
    fn assert_send<T: Send>() {}
    assert_send::<Quantum>();
    assert_send::<QuantumOutcome>();
}

/// Render a caught panic payload for the session's error field (the two
/// payload types `panic!` produces, plus a fallback for exotic ones).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::OptSpec;

    fn synth_cfg(seed: u64, steps: usize) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.workload = "rosenbrock".into();
        cfg.steps = steps;
        cfg.seed = seed;
        cfg.synth_dim = 48;
        cfg.optimizer = OptSpec::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        cfg.optex.parallelism = 3;
        cfg.optex.t0 = 5;
        cfg.optex.threads = 1;
        cfg
    }

    use crate::testutil::fixtures::tmp_ckpt_dir as tmp_dir;

    #[test]
    fn runs_to_done_with_default_budget() {
        let dir = tmp_dir("done");
        let mut s =
            Session::build(1, synth_cfg(3, 7), Budget::default(), &dir).unwrap();
        assert_eq!(s.state(), SessionState::Pending);
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.state(), SessionState::Done);
        assert_eq!(s.iters_done(), 7);
        assert_eq!(s.stop_reason(), Some("max_iters"));
        assert_eq!(s.rows().len(), 7);
        assert!(s.theta().is_some());
        assert!(s.best_loss().is_finite());
        // finish released the driver but kept the arena counters
        let (allocs, copied) = s.grad_counters().unwrap();
        assert_eq!(allocs, 2);
        assert_eq!(copied, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn max_iters_budget_overrides_cfg_steps() {
        let dir = tmp_dir("budget");
        let budget = Budget { max_iters: Some(3), ..Budget::default() };
        let mut s = Session::build(1, synth_cfg(3, 50), budget, &dir).unwrap();
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.iters_done(), 3);
        assert_eq!(s.state(), SessionState::Done);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn max_iters_zero_runs_no_iteration() {
        let dir = tmp_dir("zero");
        let budget = Budget { max_iters: Some(0), ..Budget::default() };
        let mut s = Session::build(1, synth_cfg(3, 50), budget, &dir).unwrap();
        s.step();
        assert_eq!(s.state(), SessionState::Done);
        assert_eq!(s.iters_done(), 0, "a zero budget must not run a fan-out");
        assert_eq!(s.stop_reason(), Some("max_iters"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn target_loss_budget_stops_early() {
        let dir = tmp_dir("target");
        let budget = Budget { target_loss: Some(f64::INFINITY), ..Budget::default() };
        let mut s = Session::build(1, synth_cfg(3, 50), budget, &dir).unwrap();
        s.step();
        assert_eq!(s.state(), SessionState::Done);
        assert_eq!(s.stop_reason(), Some("target_loss"));
        assert_eq!(s.iters_done(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn suspend_releases_driver_and_resume_continues_bit_identically() {
        let dir = tmp_dir("suspend");
        // solo reference
        let cfg = synth_cfg(9, 10);
        let mut solo = Session::build(1, cfg.clone(), Budget::default(), &dir).unwrap();
        while solo.is_runnable() {
            solo.step();
        }
        // paused copy: 4 iters, suspend, resume, finish
        let mut s = Session::build(2, cfg, Budget::default(), &dir).unwrap();
        for _ in 0..4 {
            s.step();
        }
        s.pause().unwrap();
        assert!(s.is_suspended(), "factory session pause must drop the driver");
        assert!(s.theta().is_none(), "iterate lives in the checkpoint while suspended");
        s.step(); // no-op while paused
        assert_eq!(s.iters_done(), 4);
        s.resume().unwrap();
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.state(), SessionState::Done);
        let a = solo.theta().unwrap();
        let b = s.theta().unwrap();
        assert_eq!(a, b, "suspend/resume changed the trajectory");
        let solo_bits: Vec<u64> =
            solo.rows().iter().map(|r| r.loss.to_bits()).collect();
        let bits: Vec<u64> = s.rows().iter().map(|r| r.loss.to_bits()).collect();
        assert_eq!(solo_bits, bits);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn suspend_resume_is_bit_identical_for_stochastic_oracles() {
        // ISSUE 5: the v2 checkpoint carries the oracle's sampler state,
        // so a NOISY synth session suspends/resumes exactly — previously
        // only deterministic oracles did.
        let dir = tmp_dir("noisy_suspend");
        let mut cfg = synth_cfg(5, 9);
        cfg.workload = "ackley".into();
        cfg.noise_std = 0.35;
        let mut solo = Session::build(1, cfg.clone(), Budget::default(), &dir).unwrap();
        while solo.is_runnable() {
            solo.step();
        }
        let mut s = Session::build(2, cfg, Budget::default(), &dir).unwrap();
        for _ in 0..3 {
            s.step();
        }
        s.pause().unwrap();
        assert!(s.is_suspended());
        s.resume().unwrap();
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(
            solo.theta().unwrap(),
            s.theta().unwrap(),
            "noisy suspend/resume changed the trajectory"
        );
        let solo_bits: Vec<u64> = solo.rows().iter().map(|r| r.loss.to_bits()).collect();
        let bits: Vec<u64> = s.rows().iter().map(|r| r.loss.to_bits()).collect();
        assert_eq!(solo_bits, bits);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adopted_suspended_session_resumes_from_checkpoint() {
        let dir = tmp_dir("adopt");
        let cfg = synth_cfg(11, 8);
        let mut solo = Session::build(1, cfg.clone(), Budget::default(), &dir).unwrap();
        while solo.is_runnable() {
            solo.step();
        }
        // original server: run 3 iters, suspend, then "die" (drop)
        let mut orig = Session::build(7, cfg.clone(), Budget::default(), &dir).unwrap();
        for _ in 0..3 {
            orig.step();
        }
        orig.pause().unwrap();
        let iters = orig.iters_done();
        drop(orig);
        // adopting server: re-register from manifest data, resume
        let mut s = Session::adopt(7, cfg.clone(), Budget::default(), &dir, iters);
        assert_eq!(s.state(), SessionState::Paused);
        assert!(s.is_suspended());
        s.resume().unwrap();
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.state(), SessionState::Done);
        assert_eq!(
            solo.theta().unwrap(),
            s.theta().unwrap(),
            "adopted resume diverged from an uninterrupted run"
        );
        // adopted-at-zero (was running, never suspended): re-runs fresh
        let mut z = Session::adopt(8, cfg, Budget::default(), &dir, 0);
        z.resume().unwrap();
        while z.is_runnable() {
            z.step();
        }
        assert_eq!(z.theta().unwrap(), solo.theta().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adopt_crash_window_stray_checkpoint_is_recovered() {
        // kill landing BETWEEN the suspend-checkpoint write and the
        // manifest rewrite: the manifest entry says iters 0 / no ckpt,
        // but session_<id>.ckpt exists on disk. Resume must prefer the
        // checkpoint (newer truth) and, for a torn write, fall back to
        // the seed re-run — never permanently Fail the session.
        let dir = tmp_dir("straychk");
        let cfg = synth_cfg(21, 8);
        let mut solo = Session::build(1, cfg.clone(), Budget::default(), &dir).unwrap();
        while solo.is_runnable() {
            solo.step();
        }
        let mut orig = Session::build(4, cfg.clone(), Budget::default(), &dir).unwrap();
        for _ in 0..3 {
            orig.step();
        }
        orig.pause().unwrap();
        drop(orig); // the manifest never heard about this suspend
        let mut s = Session::adopt(4, cfg.clone(), Budget::default(), &dir, 0);
        s.resume().unwrap();
        assert_eq!(s.iters_done(), 3, "stray checkpoint must be adopted, not ignored");
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.theta().unwrap(), solo.theta().unwrap());

        // torn write (truncated stray checkpoint): seed re-run, not Failed
        let mut orig = Session::build(5, cfg.clone(), Budget::default(), &dir).unwrap();
        for _ in 0..2 {
            orig.step();
        }
        orig.pause().unwrap();
        drop(orig);
        let path = dir.join("session_5.ckpt");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let mut z = Session::adopt(5, cfg, Budget::default(), &dir, 0);
        z.resume().unwrap();
        assert_eq!(z.iters_done(), 0, "torn checkpoint falls back to seed re-run");
        assert!(!path.exists(), "torn checkpoint must be cleaned up");
        while z.is_runnable() {
            z.step();
        }
        assert_eq!(z.state(), SessionState::Done);
        assert_eq!(z.theta().unwrap(), solo.theta().unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_resume_marks_session_failed_with_reason() {
        let dir = tmp_dir("badresume");
        let mut s = Session::build(1, synth_cfg(2, 20), Budget::default(), &dir).unwrap();
        for _ in 0..2 {
            s.step();
        }
        s.pause().unwrap();
        // truncate the suspend checkpoint: resume must fail cleanly,
        // mark the session Failed, and keep the error queryable
        let path = dir.join("session_1.ckpt");
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        let err = s.resume().unwrap_err().to_string();
        assert!(err.contains("resume failed"), "{err}");
        assert_eq!(s.state(), SessionState::Failed);
        assert!(s.error().unwrap().contains("resume failed"));
        assert!(!s.is_runnable());

        // missing checkpoint with recorded progress is the same class
        let mut m = Session::adopt(3, synth_cfg(2, 20), Budget::default(), &dir, 5);
        assert!(m.resume().is_err());
        assert_eq!(m.state(), SessionState::Failed);
        assert!(m.error().unwrap().contains("missing"), "{:?}", m.error());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_entry_only_for_rebuildable_active_sessions() {
        let dir = tmp_dir("mentry");
        let mut cfg = synth_cfg(1, 4);
        cfg.workload = "sphere".into();
        let budget = Budget { max_iters: Some(3), ..Budget::default() };
        let mut s = Session::build(2, cfg, budget, &dir).unwrap();
        let e = s.manifest_entry().expect("factory session is adoptable");
        assert_eq!(e.id, 2);
        assert_eq!(e.state, "pending");
        assert_eq!(e.budget.max_iters, Some(3));
        assert!(e.ckpt.is_none());
        assert!(e.overrides.iter().any(|o| o == "workload=\"sphere\""), "{:?}", e.overrides);
        s.step();
        s.pause().unwrap();
        let e = s.manifest_entry().unwrap();
        assert_eq!(e.state, "paused");
        assert_eq!(e.iters, 1);
        assert_eq!(e.ckpt.as_deref(), Some("session_2.ckpt"));
        s.resume().unwrap();
        while s.is_runnable() {
            s.step();
        }
        assert!(s.manifest_entry().is_none(), "finished sessions are not adoptable");
        // injected-oracle sessions are never listed
        let src = crate::testutil::fixtures::dqn_replay_source(3);
        let inj =
            Session::with_source(5, synth_cfg(3, 2), Box::new(src), Budget::default())
                .unwrap();
        assert!(inj.manifest_entry().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn state_machine_rejects_bad_transitions() {
        let dir = tmp_dir("fsm");
        let mut s = Session::build(1, synth_cfg(0, 2), Budget::default(), &dir).unwrap();
        assert!(s.resume().is_err(), "resume of a pending session");
        while s.is_runnable() {
            s.step();
        }
        assert!(s.pause().is_err(), "pause of a done session");
        assert!(s.cancel().is_err(), "cancel of a done session");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn panicking_oracle_is_quarantined_not_propagated() {
        let dir = tmp_dir("quarantine");
        let mut cfg = synth_cfg(3, 6);
        cfg.faults = "eval_panic@i2".into();
        let mut s = Session::build(1, cfg, Budget::default(), &dir).unwrap();
        s.set_obs(crate::obs::Registry::new());
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.state(), SessionState::Failed);
        assert!(s.quarantined());
        assert_eq!(
            s.stop_reason(),
            Some("quarantined"),
            "quarantine must carry a uniform stop reason (ISSUE 9 satellite)"
        );
        let err = s.error().unwrap();
        assert!(err.contains("panic in Driver::iteration"), "{err}");
        assert!(err.contains("injected fault: eval_panic"), "{err}");
        assert_eq!(s.iters_done(), 1, "the panicking iteration never counted");
        assert!(s.theta().is_none() || s.theta().unwrap().iter().all(|v| v.is_finite()));
        // the flight recorder names the fault site, the iteration it
        // fired at, and the quarantine — and the post-mortem artifact
        // was dumped next to the checkpoints
        let trace = s.trace_lines().join("\n");
        #[cfg(feature = "obs")]
        assert!(trace.contains("i2 fault eval_panic"), "{trace}");
        assert!(trace.contains("quarantine"), "{trace}");
        assert!(trace.contains("finish quarantined"), "{trace}");
        let dumped = std::fs::read_to_string(dir.join("trace_1.txt")).unwrap();
        assert!(dumped.contains("quarantine"), "{dumped}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retry_counter_survives_suspend_cycles() {
        let dir = tmp_dir("counters");
        let mut cfg = synth_cfg(3, 6);
        cfg.faults = "eval_err@i2".into();
        cfg.optex.retry_max = 1;
        let mut s = Session::build(1, cfg, Budget::default(), &dir).unwrap();
        for _ in 0..3 {
            s.step();
        }
        assert_eq!(s.retries(), 1);
        assert_eq!(s.nonfinite(), 0);
        s.pause().unwrap();
        assert_eq!(s.retries(), 1, "archived across the suspend");
        s.resume().unwrap();
        while s.is_runnable() {
            s.step();
        }
        assert_eq!(s.state(), SessionState::Done);
        assert_eq!(s.retries(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cancel_is_terminal_failed_with_reason() {
        let dir = tmp_dir("cancel");
        let mut s = Session::build(1, synth_cfg(0, 50), Budget::default(), &dir).unwrap();
        s.step();
        s.cancel().unwrap();
        assert_eq!(s.state(), SessionState::Failed);
        assert_eq!(s.error(), Some("cancelled by client"));
        assert!(!s.is_runnable());
        std::fs::remove_dir_all(&dir).ok();
    }
}
