//! From-scratch CLI argument parser (no `clap` offline).
//!
//! Grammar: `optex <subcommand> [positionals...] [--flag] [--key value]
//! [--set cfg.key=value ...]`. Unknown options are errors (never silently
//! ignored); `--help` is handled by the caller via [`Args::flag`].

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-option token (e.g. `run`, `fig`, `bench`).
    pub subcommand: Option<String>,
    /// Remaining non-option tokens in order.
    pub positionals: Vec<String>,
    /// `--key value` options (last occurrence wins except `--set`).
    options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Repeatable `--set key=value` config overrides, in order.
    pub sets: Vec<String>,
}

/// CLI parse error.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Option names that take a value; everything else starting with `--` is
/// a boolean flag. Keeping this table explicit makes typos hard errors.
const VALUE_OPTS: &[&str] = &[
    "config", "out", "artifacts", "method", "workload", "steps", "seed",
    "seeds", "fig", "profile", "n", "t0", "filter", "lr", "optimizer",
    "episodes", "env", "backend", "dim", "checkpoint", "resume", "fit",
    "threads", "gp-refresh-every", "pool", "addr", "max-sessions", "policy",
    "dir", "faults", "steppers", "metrics-addr", "workers", "worker-bin",
];

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(CliError("bare `--` not supported".into()));
                }
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    args.insert(k, v.to_string())?;
                    continue;
                }
                if name == "set" {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError("--set needs key=value".into()))?;
                    args.sets.push(v);
                } else if VALUE_OPTS.contains(&name) {
                    let v = it.next().ok_or_else(|| {
                        CliError(format!("--{name} needs a value"))
                    })?;
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    fn insert(&mut self, k: &str, v: String) -> Result<(), CliError> {
        if k == "set" {
            self.sets.push(v);
            Ok(())
        } else if VALUE_OPTS.contains(&k) {
            self.options.insert(k.to_string(), v);
            Ok(())
        } else {
            Err(CliError(format!("unknown option --{k}")))
        }
    }

    /// String option value.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Parsed numeric option.
    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.options.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected integer, got {s:?}"))),
        }
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.options.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected number, got {s:?}"))),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Reject flags that no subcommand understands (call after dispatch
    /// decides which flags it consumed).
    pub fn check_known_flags(&self, known: &[&str]) -> Result<(), CliError> {
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                return Err(CliError(format!("unknown flag --{f}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("run --config configs/fig2.toml --steps 100 --paper --set optex.t0=20");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("config"), Some("configs/fig2.toml"));
        assert_eq!(a.opt_usize("steps").unwrap(), Some(100));
        assert!(a.flag("paper"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.sets, vec!["optex.t0=20"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("fig --fig=2 --seed=9");
        assert_eq!(a.opt("fig"), Some("2"));
        assert_eq!(a.opt_usize("seed").unwrap(), Some(9));
    }

    #[test]
    fn positionals_collected() {
        let a = parse("fig 2 6a");
        assert_eq!(a.subcommand.as_deref(), Some("fig"));
        assert_eq!(a.positionals, vec!["2", "6a"]);
    }

    #[test]
    fn repeated_sets_preserved_in_order() {
        let a = parse("run --set a=1 --set b=2");
        assert_eq!(a.sets, vec!["a=1", "b=2"]);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(["--steps".to_string()]).is_err());
        assert!(Args::parse(["--unknown=3".to_string()]).is_err());
        assert!(Args::parse(["--".to_string()]).is_err());
        let a = parse("run --verbose");
        assert!(a.check_known_flags(&["quiet"]).is_err());
        assert!(a.check_known_flags(&["verbose"]).is_ok());
    }

    #[test]
    fn bad_numeric_value() {
        let a = parse("run --steps ten");
        assert!(a.opt_usize("steps").is_err());
    }

    // -- ISSUE 4 satellite: the serve subcommand makes the parser
    // multi-mode; pin every parse path it leans on -----------------------

    #[test]
    fn serve_subcommand_options_parse() {
        let a = parse(
            "serve --addr 127.0.0.1:0 --max-sessions 16 --threads 8 \
             --pool persistent --policy fair --set serve.ckpt_dir=/tmp/ck",
        );
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt("addr"), Some("127.0.0.1:0"));
        assert_eq!(a.opt_usize("max-sessions").unwrap(), Some(16));
        assert_eq!(a.opt_usize("threads").unwrap(), Some(8));
        assert_eq!(a.opt("pool"), Some("persistent"));
        assert_eq!(a.opt("policy"), Some("fair"));
        assert_eq!(a.sets, vec!["serve.ckpt_dir=/tmp/ck"]);
        assert!(a.positionals.is_empty());
    }

    #[test]
    fn serve_adopt_is_a_bare_flag() {
        // --adopt takes no value: it must land in the flag list, pass a
        // dispatch that knows it, and not swallow the next token
        let a = parse("serve --adopt --addr 127.0.0.1:0");
        assert!(a.flag("adopt"));
        assert_eq!(a.opt("addr"), Some("127.0.0.1:0"));
        assert!(a.check_known_flags(&["help", "adopt"]).is_ok());
        assert!(a.check_known_flags(&["help"]).is_err());
    }

    #[test]
    fn unknown_value_option_in_equals_form_is_rejected() {
        // the VALUE_OPTS table is the only thing standing between a typo
        // and a silently ignored flag — both spellings must hard-error
        let err = Args::parse(["serve".into(), "--adress=1.2.3.4:5".to_string()])
            .unwrap_err();
        assert!(err.to_string().contains("unknown option --adress"), "{err}");
        // space form: an unknown name becomes a bare flag, caught by
        // check_known_flags after dispatch
        let a = parse("serve --verbose");
        let err = a.check_known_flags(&["help"]).unwrap_err();
        assert!(err.to_string().contains("unknown flag --verbose"), "{err}");
    }

    #[test]
    fn opt_usize_and_opt_f64_error_messages_name_flag_and_value() {
        let a = parse("serve --max-sessions many --lr fast");
        let err = a.opt_usize("max-sessions").unwrap_err().to_string();
        assert!(err.contains("--max-sessions"), "{err}");
        assert!(err.contains("expected integer"), "{err}");
        assert!(err.contains("\"many\""), "{err}");
        let err = a.opt_f64("lr").unwrap_err().to_string();
        assert!(err.contains("--lr"), "{err}");
        assert!(err.contains("expected number"), "{err}");
        assert!(err.contains("\"fast\""), "{err}");
        // absent options are None, not errors
        assert_eq!(a.opt_usize("steps").unwrap(), None);
        assert_eq!(a.opt_f64("noise").unwrap(), None);
        // negative numbers fail usize but pass f64
        let a = parse("serve --max-sessions -3 --lr -0.5");
        assert!(a.opt_usize("max-sessions").is_err());
        assert_eq!(a.opt_f64("lr").unwrap(), Some(-0.5));
    }

    #[test]
    fn check_known_flags_ignores_value_options_and_sets() {
        // value options and --set never land in the flag list
        let a = parse("serve --addr x:1 --set a=1 --help");
        assert!(a.check_known_flags(&["help"]).is_ok());
        // multiple unknown flags: the first one is reported
        let a = parse("run --alpha --beta");
        let err = a.check_known_flags(&[]).unwrap_err().to_string();
        assert!(err.contains("--alpha"), "{err}");
    }

    #[test]
    fn value_option_missing_its_value_is_an_error() {
        for opt in ["--addr", "--max-sessions", "--policy", "--pool"] {
            let err = Args::parse(["serve".to_string(), opt.to_string()]).unwrap_err();
            assert!(
                err.to_string().contains("needs a value"),
                "{opt}: {err}"
            );
        }
    }

    #[test]
    fn last_occurrence_wins_for_value_options() {
        let a = parse("serve --addr a:1 --addr b:2");
        assert_eq!(a.opt("addr"), Some("b:2"));
    }

    // -- ISSUE 10: the router subcommand's surface -----------------------

    #[test]
    fn router_subcommand_options_parse() {
        let a = parse(
            "router --addr 127.0.0.1:7979 --workers 4 --dir results/router \
             --worker-bin target/release/optex --set serve.max_sessions=8",
        );
        assert_eq!(a.subcommand.as_deref(), Some("router"));
        assert_eq!(a.opt("addr"), Some("127.0.0.1:7979"));
        assert_eq!(a.opt_usize("workers").unwrap(), Some(4));
        assert_eq!(a.opt("dir"), Some("results/router"));
        assert_eq!(a.opt("worker-bin"), Some("target/release/optex"));
        assert_eq!(a.sets, vec!["serve.max_sessions=8"]);
        // both take values — bare forms must hard-error, not become flags
        for opt in ["--workers", "--worker-bin"] {
            let err = Args::parse(["router".to_string(), opt.to_string()]).unwrap_err();
            assert!(err.to_string().contains("needs a value"), "{opt}: {err}");
        }
    }
}
