//! `docs/PROTOCOL.md` as an executable artifact (ISSUE 10).
//!
//! The protocol document is normative: every response shape the serve
//! and router tiers can emit is written down there as a
//! `### response: <name>` table. This module parses those tables (plus
//! the verb and error-code tables) into [`Shapes`] and validates live
//! wire lines against them — **both directions**: a missing `always`
//! field fails, and an *undocumented* field fails too, so code and
//! document cannot drift apart silently. The wire-conformance suite
//! (`rust/tests/wire_conformance.rs`) and the router integration test
//! share this one implementation; it lives in `testutil` because
//! integration tests are separate crates that cannot share helpers any
//! other way.
//!
//! The parser understands exactly the conventions PROTOCOL.md declares
//! for itself (backticked field names, `\|`-escaped type unions,
//! `always`/`optional` presence, dotted paths for nested objects) and
//! nothing more — it is a checker for one repo-owned document, not a
//! markdown library.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// One documented field of a response shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FieldSpec {
    /// `|`-joined type union (`bool`, `int`, `number`, `string`,
    /// `array`, `object`, `null`), unescaped.
    pub ty: String,
    /// `always` (required) vs `optional`.
    pub required: bool,
}

/// Every `### response: <name>` table of the document.
#[derive(Clone, Debug, Default)]
pub struct Shapes {
    shapes: BTreeMap<String, BTreeMap<String, FieldSpec>>,
}

/// Read the repo's protocol document (the workspace manifest lives at
/// the repo root, so the path resolves from any test crate).
pub fn protocol_doc() -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("docs/PROTOCOL.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

fn cells(line: &str) -> Vec<String> {
    // `\|` inside a cell is an escaped literal pipe (type unions);
    // protect it before splitting on the column separator
    let protected = line.replace("\\|", "\u{1}");
    protected
        .trim()
        .trim_matches('|')
        .split('|')
        .map(|c| c.trim().replace('\u{1}', "|"))
        .collect()
}

fn unticked(cell: &str) -> String {
    cell.trim_matches('`').to_string()
}

fn is_separator(row: &[String]) -> bool {
    row.iter().all(|c| !c.is_empty() && c.chars().all(|ch| ch == '-' || ch == ':'))
}

/// The first markdown table after byte offset `from`: its data rows
/// (header and `---` separator dropped), each as trimmed cells.
fn first_table(doc: &str, from: usize) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut in_table = false;
    for line in doc[from..].lines() {
        let t = line.trim();
        if t.starts_with('|') {
            in_table = true;
            let row = cells(t);
            if !is_separator(&row) {
                rows.push(row);
            }
        } else if in_table {
            break; // table ended
        } else if t.starts_with('#') {
            break; // next heading before any table
        }
    }
    if !rows.is_empty() {
        rows.remove(0); // header row
    }
    rows
}

impl Shapes {
    /// Parse every `### response: <name>` table of the document.
    pub fn parse(doc: &str) -> Shapes {
        let mut shapes = BTreeMap::new();
        let mut offset = 0;
        for line in doc.lines() {
            let here = offset;
            offset += line.len() + 1;
            let Some(name) = line.trim().strip_prefix("### response:") else {
                continue;
            };
            let name = name.trim().to_string();
            let mut fields = BTreeMap::new();
            for row in first_table(doc, here + line.len()) {
                assert!(
                    row.len() >= 3,
                    "shape {name}: bad table row {row:?} in PROTOCOL.md"
                );
                let required = match row[2].as_str() {
                    "always" => true,
                    "optional" => false,
                    other => panic!("shape {name}: bad presence {other:?}"),
                };
                fields.insert(
                    unticked(&row[0]),
                    FieldSpec { ty: row[1].clone(), required },
                );
            }
            assert!(!fields.is_empty(), "shape {name} has no table");
            let prev = shapes.insert(name.clone(), fields);
            assert!(prev.is_none(), "shape {name} documented twice");
        }
        Shapes { shapes }
    }

    /// The documented shape names.
    pub fn names(&self) -> Vec<&str> {
        self.shapes.keys().map(String::as_str).collect()
    }

    /// Validate one wire line against shape `name`. `Err` carries every
    /// violation (missing required field, type mismatch, undocumented
    /// field) — callers assert on it with the offending line in hand.
    pub fn conform(&self, name: &str, v: &Json) -> Result<(), String> {
        let spec = self
            .shapes
            .get(name)
            .ok_or_else(|| format!("shape {name:?} is not documented in PROTOCOL.md"))?;
        let obj = v.as_obj().ok_or_else(|| format!("{name}: response is not an object"))?;
        let mut errs = Vec::new();
        for (field, fs) in spec {
            match lookup(v, field) {
                Some(got) => {
                    if !type_ok(&fs.ty, got) {
                        errs.push(format!("{name}.{field}: want {}, got {got:?}", fs.ty));
                    }
                }
                None if fs.required => errs.push(format!("{name}.{field}: missing")),
                None => {}
            }
        }
        // strictness: every key on the wire must be documented — at the
        // top level, and inside any nested object the spec reaches into
        // with a dotted path (e.g. `error.code`)
        for key in obj.keys() {
            if !spec.contains_key(key) {
                errs.push(format!("{name}.{key}: undocumented field on the wire"));
            }
        }
        for field in spec.keys().filter(|f| f.contains('.')) {
            let parent = field.split('.').next().unwrap();
            if let Some(inner) = obj.get(parent).and_then(Json::as_obj) {
                for key in inner.keys() {
                    let dotted = format!("{parent}.{key}");
                    if !spec.contains_key(dotted.as_str()) {
                        errs.push(format!(
                            "{name}.{dotted}: undocumented field on the wire"
                        ));
                    }
                }
            }
        }
        errs.sort();
        errs.dedup();
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    /// [`Shapes::conform`] that panics with the raw line (the test-side
    /// ergonomic form).
    pub fn assert_conforms(&self, name: &str, line: &str) -> Json {
        let v = Json::parse(line)
            .unwrap_or_else(|e| panic!("unparseable wire line {line:?}: {e}"));
        if let Err(e) = self.conform(name, &v) {
            panic!("wire line does not conform to {name}: {e}\n  line: {line}");
        }
        v
    }
}

fn lookup<'a>(v: &'a Json, dotted: &str) -> Option<&'a Json> {
    let mut cur = v;
    for part in dotted.split('.') {
        cur = cur.get(part)?;
    }
    Some(cur)
}

fn type_ok(union: &str, v: &Json) -> bool {
    union.split('|').any(|ty| match ty {
        "bool" => matches!(v, Json::Bool(_)),
        "int" => v.as_usize().is_some(),
        "number" => matches!(v, Json::Num(_)),
        "string" => matches!(v, Json::Str(_)),
        "array" => matches!(v, Json::Arr(_)),
        "object" => matches!(v, Json::Obj(_)),
        "null" => matches!(v, Json::Null),
        other => panic!("unknown type {other:?} in PROTOCOL.md"),
    })
}

/// The `## Error codes` table's slugs, in document order.
pub fn parse_error_codes(doc: &str) -> Vec<String> {
    // anchored to a line start: the intro prose mentions the heading in
    // backticks, which a bare `find` would hit first
    let heading = "\n## Error codes\n";
    let at = doc.find(heading).expect("PROTOCOL.md has an Error codes section");
    first_table(doc, at + heading.len())
        .into_iter()
        .map(|row| unticked(&row[0]))
        .collect()
}

/// The `## Verbs` table: verb → success-response shape name.
pub fn parse_verbs(doc: &str) -> Vec<(String, String)> {
    let heading = "\n## Verbs\n";
    let at = doc.find(heading).expect("PROTOCOL.md has a Verbs section");
    first_table(doc, at + heading.len())
        .into_iter()
        .map(|row| (unticked(&row[0]), row[1].clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::{self, ErrCode, Proto};

    // These unit tests run in tier-1 `cargo test`, so the document and
    // the schema module cannot drift even before the (heavier) live
    // conformance suite runs.

    #[test]
    fn document_parses_and_covers_the_wire_surface() {
        let doc = protocol_doc();
        let shapes = Shapes::parse(&doc);
        for name in [
            "error-v1",
            "error-v2",
            "hello",
            "submit-ack",
            "ack",
            "watch-ack",
            "session",
            "status",
            "status-all",
            "result",
            "iter-event",
            "result-event",
            "export",
            "import-ack",
            "migrate-ack",
            "stats",
            "router-stats",
            "router-stats-worker",
            "trace",
            "shutdown-ack",
        ] {
            assert!(shapes.names().contains(&name), "shape {name} missing");
        }
        let verbs = parse_verbs(&doc);
        for (verb, shape) in &verbs {
            assert!(
                shapes.names().contains(&shape.as_str()),
                "verb {verb} maps to undocumented shape {shape}"
            );
        }
        let documented: Vec<&str> = verbs.iter().map(|(v, _)| v.as_str()).collect();
        for verb in [
            "hello", "submit", "status", "result", "watch", "pause", "resume",
            "cancel", "export", "import", "migrate", "stats", "trace", "shutdown",
        ] {
            assert!(documented.contains(&verb), "verb {verb} undocumented");
        }
        assert_eq!(documented.len(), 14, "undocumented extra verbs: {documented:?}");
    }

    #[test]
    fn error_code_table_mirrors_the_schema_exactly() {
        let codes = parse_error_codes(&protocol_doc());
        let want: Vec<String> =
            ErrCode::ALL.iter().map(|c| c.slug().to_string()).collect();
        assert_eq!(codes, want, "PROTOCOL.md error table must mirror ErrCode::ALL");
    }

    #[test]
    fn schema_renderers_conform_to_their_documented_shapes() {
        let shapes = Shapes::parse(&protocol_doc());
        shapes.assert_conforms("hello", &protocol::hello_line());
        shapes.assert_conforms("submit-ack", &protocol::submit_line(3, "pending"));
        shapes.assert_conforms("watch-ack", &protocol::watch_line(3, 5));
        shapes.assert_conforms("shutdown-ack", &protocol::shutdown_line());
        shapes.assert_conforms("migrate-ack", &protocol::migrate_line(5, 1, "running"));
        shapes.assert_conforms(
            "error-v1",
            &protocol::error_line_for(Proto::V1, ErrCode::UnknownId, "no such session 9"),
        );
        let line =
            protocol::error_line_for(Proto::V2, ErrCode::UnknownId, "no such session 9");
        let v = shapes.assert_conforms("error-v2", &line);
        let code = v.get("error").unwrap().get("code").unwrap().as_str().unwrap();
        assert!(ErrCode::from_slug(code).is_some(), "{code}");
    }

    #[test]
    fn conformance_is_strict_in_both_directions() {
        let shapes = Shapes::parse(&protocol_doc());
        // missing required field
        let v = Json::parse(r#"{"ok":true}"#).unwrap();
        let e = shapes.conform("submit-ack", &v).unwrap_err();
        assert!(e.contains("id: missing"), "{e}");
        // undocumented field
        let v = Json::parse(r#"{"ok":true,"id":1,"state":"pending","bonus":1}"#).unwrap();
        let e = shapes.conform("submit-ack", &v).unwrap_err();
        assert!(e.contains("bonus: undocumented"), "{e}");
        // type mismatch, including inside a dotted path
        let v = Json::parse(r#"{"ok":true,"id":"one","state":"pending"}"#).unwrap();
        let e = shapes.conform("submit-ack", &v).unwrap_err();
        assert!(e.contains("want int"), "{e}");
        let v = Json::parse(r#"{"ok":false,"error":{"code":7,"msg":"x"}}"#).unwrap();
        let e = shapes.conform("error-v2", &v).unwrap_err();
        assert!(e.contains("error.code"), "{e}");
        // undocumented nested field under a dotted-spec parent
        let v = Json::parse(r#"{"ok":false,"error":{"code":"busy","msg":"x","extra":1}}"#)
            .unwrap();
        let e = shapes.conform("error-v2", &v).unwrap_err();
        assert!(e.contains("error.extra: undocumented"), "{e}");
        // null is accepted exactly where the union says so
        let v =
            Json::parse(r#"{"alive":true,"addr":"a","eval_load_us":null,"index":0,"sessions":2}"#)
                .unwrap();
        shapes.conform("router-stats-worker", &v).unwrap();
    }
}
