//! Property-testing mini-framework (S18 in DESIGN.md — `proptest` is not
//! available offline).
//!
//! [`check`] runs a property over many seeded cases; on failure it panics
//! with the case index and the exact seed so the failure replays with
//! `PROP_SEED=<seed> cargo test <name>`. No shrinking — cases are kept
//! small instead.

/// `docs/PROTOCOL.md` parsing + response conformance (ISSUE 10) — the
/// wire-conformance and router suites validate live lines against the
/// document through this one implementation.
pub mod wire;

/// Shared test fixtures (integration tests live in separate crates and
/// cannot share helpers any other way).
pub mod fixtures {
    use crate::rl::DqnSource;

    /// A native DQN oracle over a deterministically pre-filled replay
    /// buffer — episode-free, so a `Driver` can step it directly. Used
    /// by `thread_invariance` and `serve_integration` to pin the same
    /// stochastic-oracle construction on both sides of a comparison.
    /// Since ISSUE 5 the construction lives in the library proper
    /// ([`DqnSource::replay_fixture`]) because `workload = "dqn_replay"`
    /// is also a factory workload — serve sessions built on it are
    /// rebuildable and therefore suspend/adopt-able.
    pub fn dqn_replay_source(seed: u64) -> DqnSource {
        DqnSource::replay_fixture(seed)
    }

    /// Per-test scratch directory (serve checkpoint dirs etc.), unique
    /// per tag + process. Tags must be distinct across concurrent tests
    /// of one binary; callers clean up with `remove_dir_all`.
    pub fn tmp_ckpt_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("optex_ckpt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).expect("creating test ckpt dir");
        d
    }

    /// The committed scenario corpus at the repo root — the golden-
    /// trajectory harness's default `--dir`, shared with the
    /// `scenarios_corpus` integration test.
    pub fn scenarios_dir() -> std::path::PathBuf {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("scenarios")
    }

    /// Pool width for tests whose thread choice is arbitrary (results
    /// are bit-identical at any width — `thread_invariance.rs`): the CI
    /// matrix sets `OPTEX_TEST_THREADS ∈ {1, 8}` so the same suites
    /// exercise both the serial path and real fan-out. Defaults to 1.
    pub fn test_threads() -> usize {
        std::env::var("OPTEX_TEST_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1)
    }

    /// Stepper-pool width for tests whose scheduling is concurrent but
    /// whose results must not be (ISSUE 8): the CI matrix sets
    /// `OPTEX_TEST_STEPPERS ∈ {1, 4}` to replay the scenario corpus on a
    /// concurrent stepper pool against the SAME goldens. Defaults to 1
    /// (serial inline stepping).
    pub fn test_steppers() -> usize {
        std::env::var("OPTEX_TEST_STEPPERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1)
    }

    /// Minimal JSONL wire client for the serve tests and benches — the
    /// ONE implementation of the connect / send-line / read-line /
    /// skip-push protocol dance, shared by `serve_integration`,
    /// `serve_restart` and `bench_estimation` (separate crates that
    /// cannot share helpers any other way). Panics on I/O or parse
    /// failures: every caller is a test/bench where that is the right
    /// failure mode.
    pub struct WireClient {
        reader: std::io::BufReader<std::net::TcpStream>,
        writer: std::net::TcpStream,
    }

    impl WireClient {
        pub fn connect(addr: impl std::net::ToSocketAddrs + std::fmt::Debug) -> WireClient {
            let stream = std::net::TcpStream::connect(&addr)
                .unwrap_or_else(|e| panic!("connecting serve endpoint {addr:?}: {e}"));
            stream
                .set_read_timeout(Some(std::time::Duration::from_secs(60)))
                .unwrap();
            WireClient {
                reader: std::io::BufReader::new(stream.try_clone().unwrap()),
                writer: stream,
            }
        }

        pub fn send(&mut self, line: &str) {
            use std::io::Write;
            self.writer.write_all(line.as_bytes()).unwrap();
            self.writer.write_all(b"\n").unwrap();
            self.writer.flush().unwrap();
        }

        /// Next raw line (trimmed), whatever it is — the conformance
        /// suite validates these bytes before parsing.
        pub fn read_raw(&mut self) -> String {
            use std::io::BufRead;
            let mut reply = String::new();
            self.reader.read_line(&mut reply).unwrap();
            reply.trim().to_string()
        }

        /// Next line, whatever it is (response or `watch` push).
        pub fn read_json(&mut self) -> crate::util::json::Json {
            let reply = self.read_raw();
            crate::util::json::Json::parse(&reply)
                .unwrap_or_else(|e| panic!("bad wire line {reply:?}: {e}"))
        }

        /// Next NON-push line (skips `watch` events, which are the only
        /// lines carrying an `event` field).
        pub fn response(&mut self) -> crate::util::json::Json {
            loop {
                let v = self.read_json();
                if v.get("event").is_none() {
                    return v;
                }
            }
        }

        /// One request/response exchange.
        pub fn request(&mut self, line: &str) -> crate::util::json::Json {
            self.send(line);
            self.response()
        }

        /// One request → the RAW response line (pushes skipped), for
        /// shape-conformance checks over the bytes on the wire.
        pub fn request_line(&mut self, line: &str) -> String {
            self.send(line);
            loop {
                let raw = self.read_raw();
                let v = crate::util::json::Json::parse(&raw)
                    .unwrap_or_else(|e| panic!("bad wire line {raw:?}: {e}"));
                if v.get("event").is_none() {
                    return raw;
                }
            }
        }
    }

    /// Build a `submit` request line from `key -> value` config
    /// overrides — the ONE place the tests' value-typing rule lives
    /// (numeric-looking values go bare, everything else is a JSON
    /// string), instead of per-test copies of the heuristic.
    pub fn submit_json(overrides: &[(&str, String)], paused: bool) -> String {
        use crate::util::json::Json;
        let fields: Vec<String> = overrides
            .iter()
            .map(|(k, v)| {
                let key = Json::Str(k.to_string()).to_string();
                if v.parse::<f64>().is_ok() {
                    format!("{key}:{v}")
                } else {
                    format!("{key}:{}", Json::Str(v.clone()).to_string())
                }
            })
            .collect();
        let paused_field = if paused { ",\"paused\":true" } else { "" };
        format!(
            "{{\"cmd\":\"submit\",\"config\":{{{}}}{paused_field}}}",
            fields.join(",")
        )
    }
}

pub mod prop {
    use crate::util::Rng;

    /// Number of cases per property (override with env `PROP_CASES`).
    pub fn default_cases() -> usize {
        std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64)
    }

    /// Run `property` over seeded cases. Return `Err(msg)` to fail a case.
    ///
    /// `PROP_SEED=<n>` pins a single case for replay.
    pub fn check<F>(name: &str, property: F)
    where
        F: Fn(&mut Rng) -> Result<(), String>,
    {
        check_cases(name, 0, property)
    }

    /// Like [`check`] but with a case-count floor: runs
    /// `max(min_cases, PROP_CASES-or-64)` cases. Exactness properties
    /// (e.g. the incremental-vs-reference GP differential) use this to
    /// guarantee their contractual coverage regardless of environment.
    pub fn check_cases<F>(name: &str, min_cases: usize, property: F)
    where
        F: Fn(&mut Rng) -> Result<(), String>,
    {
        if let Ok(seed) = std::env::var("PROP_SEED").map(|s| s.parse::<u64>().unwrap()) {
            let mut rng = Rng::new(seed);
            if let Err(msg) = property(&mut rng) {
                panic!("property {name} failed (replay seed {seed}): {msg}");
            }
            return;
        }
        let cases = default_cases().max(min_cases);
        for case in 0..cases {
            let seed = 0x9E3779B97F4A7C15u64
                .wrapping_mul(case as u64 + 1)
                .wrapping_add(0x5EED);
            let mut rng = Rng::new(seed);
            if let Err(msg) = property(&mut rng) {
                panic!(
                    "property {name} failed on case {case}/{cases} \
                     (replay with PROP_SEED={seed}): {msg}"
                );
            }
        }
    }

    /// Assert helper producing property-friendly errors.
    #[macro_export]
    macro_rules! prop_assert {
        ($cond:expr, $($fmt:tt)+) => {
            if !$cond {
                return Err(format!($($fmt)+));
            }
        };
    }

    /// Random SPD matrix (row-major) with the given jitter.
    pub fn gen_spd(rng: &mut Rng, n: usize, jitter: f64) -> Vec<f64> {
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s + if i == j { jitter } else { 0.0 };
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0usize);
        prop::check("trivial", |rng| {
            count.set(count.get() + 1);
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
        assert_eq!(count.get(), prop::default_cases());
        let _ = &mut count;
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop::check("always_fails", |_| Err("boom".into()));
    }

    #[test]
    fn check_cases_enforces_the_floor() {
        let count = std::cell::Cell::new(0usize);
        let floor = prop::default_cases() + 37;
        prop::check_cases("floored", floor, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), floor);
    }

    #[test]
    fn gen_spd_is_spd() {
        prop::check("spd", |rng| {
            let n = 1 + rng.below(12);
            let a = prop::gen_spd(rng, n, 0.5);
            crate::gp::cholesky::chol_solve(&a, n, &vec![1.0; n])
                .map(|_| ())
                .map_err(|e| format!("not SPD: {e}"))
        });
    }
}
