//! Property-testing mini-framework (S18 in DESIGN.md — `proptest` is not
//! available offline).
//!
//! [`check`] runs a property over many seeded cases; on failure it panics
//! with the case index and the exact seed so the failure replays with
//! `PROP_SEED=<seed> cargo test <name>`. No shrinking — cases are kept
//! small instead.

/// Shared test fixtures (integration tests live in separate crates and
/// cannot share helpers any other way).
pub mod fixtures {
    use std::cell::RefCell;
    use std::rc::Rc;

    use crate::nn::Mlp;
    use crate::rl::{DqnSource, ReplayBuffer};
    use crate::util::Rng;

    /// A native DQN oracle over a deterministically pre-filled replay
    /// buffer — episode-free, so a `Driver` can step it directly. Used
    /// by `thread_invariance` and `serve_integration` to pin the same
    /// stochastic-oracle construction on both sides of a comparison.
    pub fn dqn_replay_source(seed: u64) -> DqnSource {
        let obs_dim = 6;
        let n_act = 3;
        let replay = Rc::new(RefCell::new(ReplayBuffer::new(512, obs_dim)));
        let mut rng = Rng::new(seed);
        for _ in 0..256 {
            let o = rng.normal_vec(obs_dim);
            let no = rng.normal_vec(obs_dim);
            replay.borrow_mut().push(
                &o,
                rng.below(n_act),
                rng.normal() as f32,
                &no,
                rng.coin(0.1),
            );
        }
        let mlp = Mlp::new(obs_dim, 32, n_act);
        DqnSource::native(mlp, replay, 64, 0.95, 10, seed)
    }

    /// Per-test scratch directory (serve checkpoint dirs etc.), unique
    /// per tag + process. Tags must be distinct across concurrent tests
    /// of one binary; callers clean up with `remove_dir_all`.
    pub fn tmp_ckpt_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("optex_ckpt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).expect("creating test ckpt dir");
        d
    }
}

pub mod prop {
    use crate::util::Rng;

    /// Number of cases per property (override with env `PROP_CASES`).
    pub fn default_cases() -> usize {
        std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64)
    }

    /// Run `property` over seeded cases. Return `Err(msg)` to fail a case.
    ///
    /// `PROP_SEED=<n>` pins a single case for replay.
    pub fn check<F>(name: &str, property: F)
    where
        F: Fn(&mut Rng) -> Result<(), String>,
    {
        check_cases(name, 0, property)
    }

    /// Like [`check`] but with a case-count floor: runs
    /// `max(min_cases, PROP_CASES-or-64)` cases. Exactness properties
    /// (e.g. the incremental-vs-reference GP differential) use this to
    /// guarantee their contractual coverage regardless of environment.
    pub fn check_cases<F>(name: &str, min_cases: usize, property: F)
    where
        F: Fn(&mut Rng) -> Result<(), String>,
    {
        if let Ok(seed) = std::env::var("PROP_SEED").map(|s| s.parse::<u64>().unwrap()) {
            let mut rng = Rng::new(seed);
            if let Err(msg) = property(&mut rng) {
                panic!("property {name} failed (replay seed {seed}): {msg}");
            }
            return;
        }
        let cases = default_cases().max(min_cases);
        for case in 0..cases {
            let seed = 0x9E3779B97F4A7C15u64
                .wrapping_mul(case as u64 + 1)
                .wrapping_add(0x5EED);
            let mut rng = Rng::new(seed);
            if let Err(msg) = property(&mut rng) {
                panic!(
                    "property {name} failed on case {case}/{cases} \
                     (replay with PROP_SEED={seed}): {msg}"
                );
            }
        }
    }

    /// Assert helper producing property-friendly errors.
    #[macro_export]
    macro_rules! prop_assert {
        ($cond:expr, $($fmt:tt)+) => {
            if !$cond {
                return Err(format!($($fmt)+));
            }
        };
    }

    /// Random SPD matrix (row-major) with the given jitter.
    pub fn gen_spd(rng: &mut Rng, n: usize, jitter: f64) -> Vec<f64> {
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[i * n + k] * m[j * n + k];
                }
                a[i * n + j] = s + if i == j { jitter } else { 0.0 };
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0usize);
        prop::check("trivial", |rng| {
            count.set(count.get() + 1);
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
        assert_eq!(count.get(), prop::default_cases());
        let _ = &mut count;
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED=")]
    fn failing_property_reports_seed() {
        prop::check("always_fails", |_| Err("boom".into()));
    }

    #[test]
    fn check_cases_enforces_the_floor() {
        let count = std::cell::Cell::new(0usize);
        let floor = prop::default_cases() + 37;
        prop::check_cases("floored", floor, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), floor);
    }

    #[test]
    fn gen_spd_is_spd() {
        prop::check("spd", |rng| {
            let n = 1 + rng.below(12);
            let a = prop::gen_spd(rng, n, 0.5);
            crate::gp::cholesky::chol_solve(&a, n, &vec![1.0; n])
                .map(|_| ())
                .map_err(|e| format!("not SPD: {e}"))
        });
    }
}
