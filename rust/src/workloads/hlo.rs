//! HLO-artifact-backed gradient oracles — the production request path.
//!
//! A [`HloSource`] owns an N-worker [`WorkerPool`] (one PJRT client +
//! compiled executable per worker) and a [`BatchProvider`] that turns a
//! parameter vector into the artifact's concrete inputs (sampling a data
//! minibatch where the workload is stochastic) and parses the outputs.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::datasets::{Corpus, ImageDataset};
use crate::runtime::{Manifest, TensorData, WorkerPool};
use crate::util::Rng;
use crate::workloads::{sampler_bytes, Eval, GradSource};

/// Turns θ into artifact inputs and artifact outputs into an [`Eval`].
/// `Send` for the same reason as [`GradSource`]: the owning driver moves
/// between stepper-pool workers across quanta.
pub trait BatchProvider: Send {
    /// Build the artifact input list (θ first, then sampled data).
    fn make_inputs(&mut self, params: &[f32]) -> Vec<TensorData>;

    /// Parse the artifact's output tuple into (loss, grad, aux).
    fn parse(&self, outputs: Vec<Vec<f32>>) -> Result<(f64, Vec<f32>, Option<f64>)>;

    /// Initial parameter scale (init is glorot-ish normals × scale).
    fn init_scale(&self) -> f32 {
        0.05
    }
}

/// Synthetic-function artifact: input (θ), output (f, ∇f). Optional
/// gradient noise is added rust-side (σ of Assump. 1).
pub struct SynthProvider {
    pub noise_std: f64,
    pub rng: Rng,
}

impl BatchProvider for SynthProvider {
    fn make_inputs(&mut self, params: &[f32]) -> Vec<TensorData> {
        vec![TensorData::F32(params.to_vec())]
    }

    fn parse(&self, mut outputs: Vec<Vec<f32>>) -> Result<(f64, Vec<f32>, Option<f64>)> {
        if outputs.len() != 2 {
            return Err(anyhow!("synth artifact: expected (f, grad)"));
        }
        let grad = outputs.pop().unwrap();
        let loss = outputs[0][0] as f64;
        Ok((loss, grad, None))
    }
}

/// Image-classifier artifact: (θ, x (B×in), y (B×10)) → (loss, grad, acc).
pub struct MlpProvider {
    pub dataset: ImageDataset,
    pub batch: usize,
    pub rng: Rng,
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
}

impl MlpProvider {
    pub fn new(dataset: ImageDataset, batch: usize, rng: Rng) -> MlpProvider {
        MlpProvider { dataset, batch, rng, x_buf: Vec::new(), y_buf: Vec::new() }
    }
}

impl BatchProvider for MlpProvider {
    fn make_inputs(&mut self, params: &[f32]) -> Vec<TensorData> {
        self.dataset
            .sample_batch(self.batch, &mut self.rng, &mut self.x_buf, &mut self.y_buf);
        vec![
            TensorData::F32(params.to_vec()),
            TensorData::F32(self.x_buf.clone()),
            TensorData::F32(self.y_buf.clone()),
        ]
    }

    fn parse(&self, mut outputs: Vec<Vec<f32>>) -> Result<(f64, Vec<f32>, Option<f64>)> {
        if outputs.len() != 3 {
            return Err(anyhow!("mlp artifact: expected (loss, grad, acc)"));
        }
        let acc = outputs.pop().unwrap()[0] as f64;
        let grad = outputs.pop().unwrap();
        let loss = outputs[0][0] as f64;
        Ok((loss, grad, Some(acc)))
    }
}

/// Char-transformer artifact: (θ, tokens (B×(L+1)) i32) → (loss, grad).
pub struct TfmProvider {
    pub corpus: Corpus,
    pub batch: usize,
    pub seq_plus_1: usize,
    pub rng: Rng,
    tok_buf: Vec<i32>,
}

impl TfmProvider {
    pub fn new(corpus: Corpus, batch: usize, seq_plus_1: usize, rng: Rng) -> TfmProvider {
        TfmProvider { corpus, batch, seq_plus_1, rng, tok_buf: Vec::new() }
    }
}

impl BatchProvider for TfmProvider {
    fn make_inputs(&mut self, params: &[f32]) -> Vec<TensorData> {
        self.corpus
            .sample_windows(self.batch, self.seq_plus_1, &mut self.rng, &mut self.tok_buf);
        vec![
            TensorData::F32(params.to_vec()),
            TensorData::I32(self.tok_buf.clone()),
        ]
    }

    fn parse(&self, mut outputs: Vec<Vec<f32>>) -> Result<(f64, Vec<f32>, Option<f64>)> {
        if outputs.len() != 2 {
            return Err(anyhow!("tfm artifact: expected (loss, grad)"));
        }
        let grad = outputs.pop().unwrap();
        let loss = outputs[0][0] as f64;
        Ok((loss, grad, None))
    }

    fn init_scale(&self) -> f32 {
        0.02
    }
}

/// HLO-backed [`GradSource`]: artifact + pool + provider.
pub struct HloSource {
    pool: WorkerPool,
    artifact: String,
    provider: Box<dyn BatchProvider>,
    d: usize,
    noise_std: f64,
    noise_rng: Rng,
}

impl HloSource {
    /// Build with an `n_workers`-wide pool serving `artifact`.
    pub fn new(
        artifacts_dir: PathBuf,
        artifact: &str,
        n_workers: usize,
        provider: Box<dyn BatchProvider>,
        noise_std: f64,
        seed: u64,
    ) -> Result<HloSource> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let d = manifest
            .get(artifact)
            .with_context(|| format!("workload artifact {artifact}"))?
            .dim()?;
        let pool = WorkerPool::spawn(artifacts_dir, vec![artifact.to_string()], n_workers)?;
        Ok(HloSource {
            pool,
            artifact: artifact.to_string(),
            provider,
            d,
            noise_std,
            noise_rng: Rng::new(seed ^ 0x401_5E),
        })
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }
}

impl GradSource for HloSource {
    fn dim(&self) -> usize {
        self.d
    }

    fn eval_batch(
        &mut self,
        points: &[&[f32]],
        grads: &mut [&mut [f32]],
    ) -> Result<Vec<Eval>> {
        debug_assert_eq!(points.len(), grads.len());
        // Sample all minibatches up front (provider RNG stays sequential
        // and reproducible), then scatter over the pool.
        let jobs: Vec<(&str, Vec<TensorData>)> = points
            .iter()
            .map(|p| (self.artifact.as_str(), self.provider.make_inputs(p)))
            .collect();
        let results = self.pool.scatter(jobs)?;
        let mut evals = Vec::with_capacity(points.len());
        for (r, out) in results.into_iter().zip(grads.iter_mut()) {
            let r = r?;
            let elapsed = r.elapsed;
            let (loss, grad, aux) = self.provider.parse(r.outputs)?;
            if grad.len() != self.d {
                return Err(anyhow!(
                    "artifact {} returned grad of {} dims, expected {}",
                    self.artifact,
                    grad.len(),
                    self.d
                ));
            }
            // One copy across the PJRT output boundary, straight into the
            // caller's row; noise (Assump. 1) is fused into the same pass.
            if self.noise_std > 0.0 {
                let s = self.noise_std as f32;
                for (o, &g) in out.iter_mut().zip(&grad) {
                    *o = g + self.noise_rng.normal() as f32 * s;
                }
            } else {
                out.copy_from_slice(&grad);
            }
            evals.push(Eval { loss, aux, elapsed });
        }
        Ok(evals)
    }

    fn value(&mut self, point: &[f32]) -> Result<f64> {
        // One extra forward+backward (the artifacts are fused loss+grad);
        // only used for logging, never in the optimization loop.
        let inputs = self.provider.make_inputs(point);
        let out = self.pool.run_on(0, &self.artifact, inputs)?;
        let (loss, _, _) = self.provider.parse(out.outputs)?;
        Ok(loss)
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut rng = rng.fork(23);
        let scale = self.provider.init_scale();
        let mut p = vec![0.0f32; self.d];
        rng.fill_normal(&mut p, scale);
        p
    }

    fn backend_name(&self) -> &'static str {
        "hlo"
    }

    fn save_sampler_state(&self) -> Vec<u8> {
        // Rust-side noise stream only: synthetic HLO workloads (whose
        // sole stochasticity is this stream) resume bit-identically.
        // Provider minibatch RNGs are NOT captured — model workloads
        // keep the standing minibatch-replay caveat on resume.
        let mut out = Vec::with_capacity(4 + 6 * 8);
        sampler_bytes::push_tag(&mut out, b"HLO1");
        sampler_bytes::push_rng(&mut out, &self.noise_rng);
        out
    }

    fn load_sampler_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut inp = bytes;
        sampler_bytes::expect_tag(&mut inp, b"HLO1", "hlo")?;
        self.noise_rng = sampler_bytes::read_rng(&mut inp)?;
        Ok(())
    }
}

/// Wall-time helper reused by RL: largest elapsed among a set of evals.
pub fn max_elapsed(evals: &[Eval]) -> Duration {
    evals.iter().map(|e| e.elapsed).max().unwrap_or(Duration::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{ImageKind, N_CLASSES};

    #[test]
    fn mlp_provider_produces_valid_onehot_batches() {
        let ds = ImageDataset::generate(ImageKind::MnistLike, 30, 0);
        let mut p = MlpProvider::new(ds, 4, Rng::new(0));
        let inputs = p.make_inputs(&[0.0; 8]);
        assert_eq!(inputs.len(), 3);
        match (&inputs[1], &inputs[2]) {
            (TensorData::F32(x), TensorData::F32(y)) => {
                assert_eq!(x.len(), 4 * 784);
                assert_eq!(y.len(), 4 * N_CLASSES);
            }
            _ => panic!("wrong dtypes"),
        }
        // consecutive calls must sample fresh batches (stochastic oracle)
        let b = p.make_inputs(&[0.0; 8]);
        match (&inputs[1], &b[1]) {
            (TensorData::F32(x1), TensorData::F32(x2)) => assert_ne!(x1, x2),
            _ => unreachable!(),
        }
    }

    #[test]
    fn providers_reject_malformed_outputs() {
        let p = SynthProvider { noise_std: 0.0, rng: Rng::new(0) };
        assert!(p.parse(vec![vec![1.0]]).is_err());
        let ds = ImageDataset::generate(ImageKind::MnistLike, 10, 0);
        let mp = MlpProvider::new(ds, 2, Rng::new(0));
        assert!(mp.parse(vec![vec![1.0], vec![0.0; 4]]).is_err());
    }

    #[test]
    fn tfm_provider_windows_in_vocab() {
        let c = Corpus::from_text(crate::datasets::corpus::shakespeare());
        let mut p = TfmProvider::new(c, 2, 9, Rng::new(0));
        let inputs = p.make_inputs(&[0.0; 4]);
        match &inputs[1] {
            TensorData::I32(toks) => {
                assert_eq!(toks.len(), 2 * 9);
                assert!(toks.iter().all(|&t| (0..96).contains(&t)));
            }
            _ => panic!("wrong dtype"),
        }
    }
}
