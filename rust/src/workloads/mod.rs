//! Workload abstraction: every optimization target (synthetic function,
//! image classifier, char transformer, q-network) is a [`GradSource`] —
//! a stochastic first-order oracle over a flat θ ∈ R^d (the paper's
//! problem setup, eq. (1)).
//!
//! Two backends per workload:
//!   * native rust (synthetic functions, q-nets) — used for fast figure
//!     sweeps and as the oracle the HLO path is validated against,
//!   * AOT HLO artifacts through the PJRT worker pool (`hlo.rs`) — the
//!     production request path.
//!
//! Gradients are written into CALLER-OWNED rows (ISSUE 3): the
//! coordinator loans the `eval_batch` fan-out the exact `GradStore` arena
//! slots its pushes will occupy, so the ground-truth phase performs no
//! per-`Eval` allocation and no gradient copy. [`Eval`] carries only the
//! scalar results.

pub mod factory;
pub mod hlo;
pub mod synthetic;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::NativePool;
use crate::util::Rng;
use synthetic::SynthFn;

/// Scalar results of one ground-truth gradient evaluation ∇f(θ) (paper
/// Algo. 1 line 7). The gradient itself lands in the caller's output row
/// — see [`GradSource::eval_batch`].
#[derive(Clone, Debug)]
pub struct Eval {
    /// Sampled loss f(θ) (== F(θ) for deterministic workloads).
    pub loss: f64,
    /// Task metric (classifier accuracy, etc.), when the workload has one.
    pub aux: Option<f64>,
    /// Wall time of this single evaluation (feeds the modeled parallel
    /// time Σ_t max_i worker_{t,i}).
    pub elapsed: Duration,
}

/// A stochastic first-order oracle.
///
/// `Send` because a session's driver (which owns the oracle) is handed
/// whole to a stepper-pool worker for each quantum (ISSUE 8): only ONE
/// thread ever touches the oracle at a time, but *which* thread changes
/// between quanta. Oracles that share state in-process (e.g. the DQN
/// replay buffer between the training loop and the oracle) use
/// `Arc<Mutex<..>>` handles rather than `Rc<RefCell<..>>`.
pub trait GradSource: Send {
    /// Parameter dimension d.
    fn dim(&self) -> usize;

    /// Evaluate ground-truth gradients at each point — the Algo-1 line-6
    /// fan-out. `grads[i]` (a d-sized row, typically a loaned `GradStore`
    /// arena slot) receives ∇f(points[i]); one `Eval` of scalars per
    /// point, in order. Rows may hold stale data — implementations
    /// overwrite every element. Implementations run the points
    /// concurrently where the backend supports it.
    fn eval_batch(
        &mut self,
        points: &[&[f32]],
        grads: &mut [&mut [f32]],
    ) -> Result<Vec<Eval>>;

    /// Allocating convenience wrapper around [`GradSource::eval_batch`]:
    /// one owned gradient row per point. For tests, benches and one-shot
    /// callers — the driver hot path loans arena rows instead.
    fn eval_batch_owned(
        &mut self,
        points: &[&[f32]],
    ) -> Result<(Vec<Eval>, Vec<Vec<f32>>)> {
        let d = self.dim();
        let mut bufs: Vec<Vec<f32>> = points.iter().map(|_| vec![0.0; d]).collect();
        let evals = {
            let mut rows: Vec<&mut [f32]> =
                bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            self.eval_batch(points, &mut rows)?
        };
        Ok((evals, bufs))
    }

    /// F(θ) only (used for optimality-gap logging on synthetic runs;
    /// stochastic workloads return a fresh sample of f(θ)).
    fn value(&mut self, point: &[f32]) -> Result<f64>;

    /// Initial iterate θ₀.
    fn init_params(&self, rng: &mut Rng) -> Vec<f32>;

    /// Human-readable backend tag ("native", "hlo").
    fn backend_name(&self) -> &'static str;

    /// Hook called by the Driver at the start of every sequential
    /// iteration with the current iterate — stateful oracles use it
    /// (e.g. DQN target-network sync). Default: no-op.
    fn on_iteration(&mut self, _t: usize, _theta: &[f32]) {}

    /// Install the shared native compute pool that [`GradSource::eval_batch`]
    /// uses to run its points concurrently. Pool-backed backends (PJRT /
    /// HLO) ignore it — their parallelism *is* the worker pool — hence
    /// the no-op default. Implementations must keep trajectories
    /// bit-identical at any thread count (fork per-point RNG streams
    /// before dispatch, never share a stream across workers).
    fn set_compute_pool(&mut self, _pool: NativePool) {}

    /// Serialize the oracle's *sampler state* — everything that advances
    /// per evaluation and is not derivable from (θ, history): noise /
    /// minibatch RNG streams, DQN target networks. Persisted inside run
    /// checkpoints (format v2) so checkpoint-backed suspend and restart
    /// adoption continue bit-identically for stochastic oracles too
    /// (ISSUE 5 — previously only deterministic oracles resumed exactly).
    /// The default "stateless" empty vec keeps the legacy
    /// restart-from-seed behavior for sources that do not opt in.
    fn save_sampler_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state produced by [`GradSource::save_sampler_state`] on a
    /// freshly built source of the SAME config. Errs on a tag or shape
    /// mismatch (a checkpoint from a different workload).
    fn load_sampler_state(&mut self, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(
            bytes.is_empty(),
            "{}: this oracle is stateless but the checkpoint carries sampler state",
            self.backend_name()
        );
        Ok(())
    }
}

/// Little-endian byte packing shared by the [`GradSource`] sampler-state
/// implementations (no serde offline; mirrors the checkpoint module's
/// hand-rolled encoding style). Each source writes a 4-byte tag first so
/// cross-workload restores fail loudly instead of scrambling an RNG.
pub mod sampler_bytes {
    use anyhow::{bail, Result};

    use crate::util::Rng;

    pub fn push_u64(out: &mut Vec<u8>, x: u64) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    pub fn read_u64(inp: &mut &[u8]) -> Result<u64> {
        if inp.len() < 8 {
            bail!("truncated sampler state");
        }
        let (head, tail) = inp.split_at(8);
        *inp = tail;
        Ok(u64::from_le_bytes(head.try_into().unwrap()))
    }

    pub fn push_tag(out: &mut Vec<u8>, tag: &[u8; 4]) {
        out.extend_from_slice(tag);
    }

    pub fn expect_tag(inp: &mut &[u8], tag: &[u8; 4], what: &str) -> Result<()> {
        if inp.len() < 4 || &inp[..4] != tag {
            bail!("sampler state is not from a {what} oracle");
        }
        *inp = &inp[4..];
        Ok(())
    }

    /// xoshiro words + Box–Muller spare: 6 u64 slots.
    pub fn push_rng(out: &mut Vec<u8>, rng: &Rng) {
        let (s, spare) = rng.state();
        for w in s {
            push_u64(out, w);
        }
        push_u64(out, spare.is_some() as u64);
        push_u64(out, spare.unwrap_or(0.0).to_bits());
    }

    pub fn read_rng(inp: &mut &[u8]) -> Result<Rng> {
        let s = [
            read_u64(inp)?,
            read_u64(inp)?,
            read_u64(inp)?,
            read_u64(inp)?,
        ];
        let has_spare = read_u64(inp)? != 0;
        let bits = read_u64(inp)?;
        Ok(Rng::from_state(s, has_spare.then(|| f64::from_bits(bits))))
    }

    pub fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
        push_u64(out, xs.len() as u64);
        for x in xs {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn read_f32s(inp: &mut &[u8]) -> Result<Vec<f32>> {
        let n = read_u64(inp)? as usize;
        // length field is untrusted (corrupt checkpoint): compare via
        // division so an absurd count cannot overflow `n * 4` (which
        // would panic in debug builds and kill the serve thread)
        if n > inp.len() / 4 {
            bail!("truncated sampler state (f32 block)");
        }
        let (head, tail) = inp.split_at(n * 4);
        *inp = tail;
        Ok(head
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Native analytic synthetic-function oracle with optional Gaussian
/// gradient noise (Assump. 1: ∇f ~ N(∇F, σ² I); `noise_std` = σ).
pub struct NativeSynth {
    pub f: SynthFn,
    pub d: usize,
    pub noise_std: f64,
    rng: Rng,
    pool: NativePool,
}

impl NativeSynth {
    pub fn new(f: SynthFn, d: usize, noise_std: f64, seed: u64) -> NativeSynth {
        NativeSynth {
            f,
            d,
            noise_std,
            rng: Rng::new(seed ^ 0x5EED_0001),
            pool: NativePool::serial(),
        }
    }
}

impl GradSource for NativeSynth {
    fn dim(&self) -> usize {
        self.d
    }

    fn eval_batch(
        &mut self,
        points: &[&[f32]],
        grads: &mut [&mut [f32]],
    ) -> Result<Vec<Eval>> {
        let n = points.len();
        debug_assert_eq!(n, grads.len());
        // Fork one noise stream per point BEFORE dispatch, on the caller
        // thread in point order: workers never touch the shared RNG, so
        // the trajectory is bit-identical at any thread count (and the
        // master stream advances by exactly n draws per batch).
        let streams: Vec<Option<Rng>> = if self.noise_std > 0.0 {
            (0..n).map(|i| Some(self.rng.fork(i as u64))).collect()
        } else {
            vec![None; n]
        };
        // Spawn-amortization cap (bit-identical either way): each
        // evaluated element costs ≥ 2 touches (value + gradient, plus
        // optional noise); the pool widens only as far as that work pays
        // for the spawns.
        let pool = self.pool.capped_for(n, 2 * self.d);
        let f = self.f;
        let d = self.d;
        let s = self.noise_std as f32;
        // Each job owns its (noise stream, output row) pair; the rows are
        // disjoint loaned slots, written in place — no per-eval alloc.
        let jobs: Vec<(Option<Rng>, &mut [f32])> = streams
            .into_iter()
            .zip(grads.iter_mut().map(|g| &mut **g))
            .collect();
        Ok(pool.run_over(jobs, |i, (stream, out)| {
            let t0 = Instant::now();
            debug_assert_eq!(out.len(), d);
            let loss = f.value_and_grad(points[i], out);
            if let Some(mut rng) = stream {
                for g in out.iter_mut() {
                    *g += rng.normal() as f32 * s;
                }
            }
            Eval { loss, aux: None, elapsed: t0.elapsed() }
        }))
    }

    fn value(&mut self, point: &[f32]) -> Result<f64> {
        Ok(self.f.value(point))
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        // Start away from the minimizer so the optimality gap is O(1):
        // θ0 ~ minimizer + offset + N(0, 0.25) (same scheme in the JAX
        // reference runs).
        let base = self.f.minimizer_value();
        let mut rng = rng.fork(17);
        (0..self.d)
            .map(|_| base + 2.0 + 0.5 * rng.normal() as f32)
            .collect()
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn set_compute_pool(&mut self, pool: NativePool) {
        self.pool = pool;
    }

    fn save_sampler_state(&self) -> Vec<u8> {
        // The master noise stream is the only mutable sampler state (the
        // per-point streams are forked from it transiently per batch).
        let mut out = Vec::with_capacity(4 + 6 * 8);
        sampler_bytes::push_tag(&mut out, b"SYN1");
        sampler_bytes::push_rng(&mut out, &self.rng);
        out
    }

    fn load_sampler_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut inp = bytes;
        sampler_bytes::expect_tag(&mut inp, b"SYN1", "native synthetic")?;
        self.rng = sampler_bytes::read_rng(&mut inp)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_synth_eval_matches_direct() {
        let mut src = NativeSynth::new(SynthFn::Sphere, 32, 0.0, 0);
        let p = vec![2.0f32; 32];
        let (evals, grads) = src.eval_batch_owned(&[&p, &p]).unwrap();
        assert_eq!(evals.len(), 2);
        assert!((evals[0].loss - 2.0).abs() < 1e-5);
        assert_eq!(grads[0].len(), 32);
        // deterministic: both points identical
        assert_eq!(grads[0], grads[1]);
        assert!((src.value(&p).unwrap() - 2.0).abs() < 1e-5);
    }

    #[test]
    fn eval_batch_overwrites_stale_row_contents() {
        // Loaned arena slots arrive dirty; every element must be written.
        let mut src = NativeSynth::new(SynthFn::Ackley, 64, 0.0, 0);
        let p = vec![1.5f32; 64];
        let (_, clean) = src.eval_batch_owned(&[&p]).unwrap();
        let mut dirty = vec![f32::NAN; 64];
        let mut rows: Vec<&mut [f32]> = vec![dirty.as_mut_slice()];
        src.eval_batch(&[&p], &mut rows).unwrap();
        assert_eq!(dirty, clean[0], "stale row data leaked through");
    }

    #[test]
    fn noise_perturbs_gradients_with_right_scale() {
        let mut src = NativeSynth::new(SynthFn::Sphere, 2000, 0.5, 1);
        let p = vec![1.0f32; 2000];
        let (_, grads) = src.eval_batch_owned(&[&p, &p]).unwrap();
        let diffs: Vec<f64> = grads[0]
            .iter()
            .zip(&grads[1])
            .map(|(&a, &b)| (a - b) as f64)
            .collect();
        let var = diffs.iter().map(|d| d * d).sum::<f64>() / diffs.len() as f64;
        // difference of two independent N(0, 0.25) draws has var 0.5
        assert!((var - 0.5).abs() < 0.08, "var={var}");
    }

    #[test]
    fn eval_batch_noise_streams_thread_count_invariant() {
        // 2·n·d = 2·8·20000 buys several workers past the spawn-grain
        // cap, so the threaded source really fans out; results must stay
        // bit-identical.
        let d = 20_000;
        let p: Vec<f32> = (0..d).map(|i| (i as f32 * 0.01).sin()).collect();
        let points: Vec<&[f32]> = (0..8).map(|_| p.as_slice()).collect();
        let mut serial = NativeSynth::new(SynthFn::Ackley, d, 0.3, 42);
        let mut threaded = NativeSynth::new(SynthFn::Ackley, d, 0.3, 42);
        threaded.set_compute_pool(NativePool::new(8));
        let (ea, ga) = serial.eval_batch_owned(&points).unwrap();
        let (eb, gb) = threaded.eval_batch_owned(&points).unwrap();
        for ((x, y), (gx, gy)) in ea.iter().zip(&eb).zip(ga.iter().zip(&gb)) {
            assert_eq!(gx, gy, "noise stream depends on thread count");
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
        // per-point streams are independent: same input, different noise
        assert_ne!(ga[0], ga[1]);
        // the master stream advances between batches
        let (_, gc) = serial.eval_batch_owned(&points).unwrap();
        assert_ne!(ga[0], gc[0]);
    }

    #[test]
    fn sampler_state_roundtrip_replays_noise_exactly() {
        // a restored source must draw the SAME noise a continuing source
        // would — the bit-identical-resume contract for stochastic oracles
        let d = 512;
        let p = vec![0.5f32; d];
        let points: Vec<&[f32]> = (0..3).map(|_| p.as_slice()).collect();
        let mut live = NativeSynth::new(SynthFn::Ackley, d, 0.4, 9);
        live.eval_batch_owned(&points).unwrap(); // advance the stream
        let state = live.save_sampler_state();
        let (_, expect) = live.eval_batch_owned(&points).unwrap();

        let mut restored = NativeSynth::new(SynthFn::Ackley, d, 0.4, 9);
        restored.load_sampler_state(&state).unwrap();
        let (_, got) = restored.eval_batch_owned(&points).unwrap();
        assert_eq!(expect, got, "restored noise stream diverged");

        // wrong-oracle state fails loudly
        assert!(restored.load_sampler_state(b"DQN1xxxx").is_err());
        assert!(restored.load_sampler_state(b"SYN1").is_err(), "truncated");
    }

    #[test]
    fn init_params_deterministic_and_offset() {
        let src = NativeSynth::new(SynthFn::Rosenbrock, 16, 0.0, 0);
        let a = src.init_params(&mut Rng::new(5));
        let b = src.init_params(&mut Rng::new(5));
        assert_eq!(a, b);
        let mean: f32 = a.iter().sum::<f32>() / 16.0;
        assert!((mean - 3.0).abs() < 0.6, "mean={mean}"); // 1 + 2 ± noise
    }
}
