//! Synthetic benchmark functions (paper Appx B.2.1 — the *modified*
//! Ackley / Sphere / Rosenbrock with mean-normalized sums).
//!
//! Analytic values and gradients, cross-checked against the lowered JAX
//! versions through the HLO artifacts in `rust/tests/hlo_roundtrip.rs`.
//! Ackley & Sphere minimize at θ* = 0, Rosenbrock at θ* = 1, all with
//! min F = 0.

use std::f64::consts::{E, PI};

/// Numerical floor under sqrt (matches the +1e-12 in the JAX model).
const EPS: f64 = 1e-12;

/// Which synthetic function.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SynthFn {
    Ackley,
    Sphere,
    Rosenbrock,
}

impl SynthFn {
    pub fn parse(s: &str) -> Option<SynthFn> {
        match s {
            "ackley" => Some(SynthFn::Ackley),
            "sphere" => Some(SynthFn::Sphere),
            "rosenbrock" => Some(SynthFn::Rosenbrock),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SynthFn::Ackley => "ackley",
            SynthFn::Sphere => "sphere",
            SynthFn::Rosenbrock => "rosenbrock",
        }
    }

    pub const ALL: [SynthFn; 3] = [SynthFn::Ackley, SynthFn::Sphere, SynthFn::Rosenbrock];

    /// The global minimizer (broadcast over d).
    pub fn minimizer_value(&self) -> f32 {
        match self {
            SynthFn::Rosenbrock => 1.0,
            _ => 0.0,
        }
    }

    /// F(θ).
    pub fn value(&self, theta: &[f32]) -> f64 {
        let d = theta.len() as f64;
        match self {
            SynthFn::Sphere => {
                let ms: f64 =
                    theta.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / d;
                (ms + EPS).sqrt()
            }
            SynthFn::Ackley => {
                let ms: f64 =
                    theta.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / d;
                let s1 = (ms + EPS).sqrt();
                let s2: f64 =
                    theta.iter().map(|&x| (2.0 * PI * x as f64).cos()).sum::<f64>() / d;
                -20.0 * (-0.2 * s1).exp() - s2.exp() + 20.0 + E
            }
            SynthFn::Rosenbrock => {
                let mut f = 0.0;
                for w in theta.windows(2) {
                    let b = w[0] as f64;
                    let a = w[1] as f64;
                    f += 100.0 * (a - b) * (a - b) + (1.0 - b) * (1.0 - b);
                }
                f / d
            }
        }
    }

    /// ∇F(θ) written into `out`; returns F(θ).
    pub fn value_and_grad(&self, theta: &[f32], out: &mut [f32]) -> f64 {
        assert_eq!(theta.len(), out.len());
        let d = theta.len() as f64;
        match self {
            SynthFn::Sphere => {
                let f = self.value(theta);
                let inv = 1.0 / (d * f);
                for (o, &x) in out.iter_mut().zip(theta) {
                    *o = (x as f64 * inv) as f32;
                }
                f
            }
            SynthFn::Ackley => {
                let ms: f64 =
                    theta.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / d;
                let s1 = (ms + EPS).sqrt();
                let s2: f64 =
                    theta.iter().map(|&x| (2.0 * PI * x as f64).cos()).sum::<f64>() / d;
                let f = -20.0 * (-0.2 * s1).exp() - s2.exp() + 20.0 + E;
                // d/dx_i [-20 e^{-0.2 s1}] = 4 e^{-0.2 s1} x_i / (d s1)
                let c1 = 4.0 * (-0.2 * s1).exp() / (d * s1);
                // d/dx_i [-e^{s2}] = e^{s2} 2π sin(2π x_i) / d
                let c2 = s2.exp() * 2.0 * PI / d;
                for (o, &x) in out.iter_mut().zip(theta) {
                    let x = x as f64;
                    *o = (c1 * x + c2 * (2.0 * PI * x).sin()) as f32;
                }
                f
            }
            SynthFn::Rosenbrock => {
                let n = theta.len();
                out.iter_mut().for_each(|o| *o = 0.0);
                let mut f = 0.0;
                for i in 0..n.saturating_sub(1) {
                    let b = theta[i] as f64;
                    let a = theta[i + 1] as f64;
                    f += 100.0 * (a - b) * (a - b) + (1.0 - b) * (1.0 - b);
                    let g_b = (-200.0 * (a - b) - 2.0 * (1.0 - b)) / d;
                    let g_a = 200.0 * (a - b) / d;
                    out[i] += g_b as f32;
                    out[i + 1] += g_a as f32;
                }
                f / d
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn minima_are_zero() {
        let z = vec![0.0f32; 32];
        let o = vec![1.0f32; 32];
        assert!(SynthFn::Sphere.value(&z) < 1e-5);
        assert!(SynthFn::Ackley.value(&z) < 1e-3);
        assert!(SynthFn::Rosenbrock.value(&o) < 1e-12);
        assert!(SynthFn::Rosenbrock.value(&z) > 0.0);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Rng::new(3);
        for f in SynthFn::ALL {
            let theta = rng.normal_vec(24);
            let mut g = vec![0.0f32; 24];
            let v = f.value_and_grad(&theta, &mut g);
            assert!((v - f.value(&theta)).abs() < 1e-9);
            for j in [0usize, 7, 23] {
                let h = 1e-4f32;
                let mut tp = theta.clone();
                tp[j] += h;
                let mut tm = theta.clone();
                tm[j] -= h;
                let fd = (f.value(&tp) - f.value(&tm)) / (2.0 * h as f64);
                assert!(
                    (fd - g[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{f:?} grad[{j}]: fd={fd} an={}",
                    g[j]
                );
            }
        }
    }

    #[test]
    fn gradient_zero_at_minimum() {
        let mut g = vec![0.0f32; 16];
        SynthFn::Rosenbrock.value_and_grad(&vec![1.0; 16], &mut g);
        assert!(g.iter().all(|&x| x.abs() < 1e-6));
        SynthFn::Ackley.value_and_grad(&vec![0.0; 16], &mut g);
        assert!(g.iter().all(|&x| x.abs() < 1e-3));
    }

    #[test]
    fn gradient_descent_reaches_minimum() {
        // Per-function learning rates: rosenbrock's valley has curvature
        // ~O(100·d) under the paper's 1/d normalization.
        let mut rng = Rng::new(7);
        for (f, lr, iters, factor) in [
            (SynthFn::Sphere, 0.05f32, 3000usize, 0.1f64),
            (SynthFn::Rosenbrock, 1e-4, 5000, 0.5),
        ] {
            let mut theta: Vec<f32> =
                rng.normal_vec(16).iter().map(|x| x * 0.5 + 0.5).collect();
            let f0 = f.value(&theta);
            let mut g = vec![0.0f32; 16];
            for _ in 0..iters {
                f.value_and_grad(&theta, &mut g);
                for (t, &gi) in theta.iter_mut().zip(&g) {
                    *t -= lr * gi * 16.0; // undo the 1/d scaling
                }
            }
            let f1 = f.value(&theta);
            assert!(f1.is_finite() && f1 < f0 * factor, "{f:?}: {f0} -> {f1}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for f in SynthFn::ALL {
            assert_eq!(SynthFn::parse(f.name()), Some(f));
        }
        assert_eq!(SynthFn::parse("rastrigin"), None);
    }
}
