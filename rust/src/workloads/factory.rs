//! Workload factory: config → [`GradSource`] + matching GP artifact name.
//!
//! This is the launcher's dispatch table. Synthetic workloads default to
//! the native analytic backend (`hlo_workload = true` switches them to
//! their artifacts); model workloads always run through HLO since there
//! is no native implementation of the big networks (by design — L2 owns
//! the models).

use anyhow::{anyhow, bail, Context, Result};

use crate::config::RunConfig;
use crate::datasets::{corpus, Corpus, ImageDataset, ImageKind};
use crate::runtime::Manifest;
use crate::util::Rng;
use crate::workloads::hlo::{HloSource, MlpProvider, SynthProvider, TfmProvider};
use crate::workloads::synthetic::SynthFn;
use crate::workloads::{GradSource, NativeSynth};

/// Number of procedurally generated train images per image workload.
const IMG_TRAIN_N: usize = 2000;

/// A built workload: the oracle plus the name of its paired gp_estimate
/// artifact (when one exists in the manifest).
pub struct Workload {
    pub source: Box<dyn GradSource>,
    /// gp_estimate artifact for the HLO estimation backend.
    pub gp_artifact: Option<String>,
    /// Pretty name for logs.
    pub name: String,
}

/// Build the [`GradSource`] described by `cfg.workload`.
pub fn build(cfg: &RunConfig) -> Result<Workload> {
    let n = cfg.optex.parallelism;
    let seed = cfg.seed;
    let w = cfg.workload.as_str();

    if let Some(f) = SynthFn::parse(w) {
        if cfg.hlo_workload {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let artifact = manifest
                .by_family("synth")
                .find(|a| {
                    a.meta_str("fn").map(|s| s == w).unwrap_or(false)
                        && a.dim().map(|d| d == cfg.synth_dim).unwrap_or(false)
                })
                .map(|a| a.name.clone())
                .ok_or_else(|| {
                    anyhow!(
                        "no synth artifact for {w} at d={} (re-run `make artifacts`)",
                        cfg.synth_dim
                    )
                })?;
            let provider = SynthProvider { noise_std: 0.0, rng: Rng::new(seed) };
            let source = HloSource::new(
                cfg.artifacts_dir.clone(),
                &artifact,
                n,
                Box::new(provider),
                cfg.noise_std,
                seed,
            )?;
            return Ok(Workload {
                source: Box::new(source),
                gp_artifact: Some("gp_synth".into()),
                name: format!("{w}(hlo,d={})", cfg.synth_dim),
            });
        }
        let source = NativeSynth::new(f, cfg.synth_dim, cfg.noise_std, seed);
        return Ok(Workload {
            source: Box::new(source),
            gp_artifact: Some("gp_synth".into()),
            name: format!("{w}(native,d={})", cfg.synth_dim),
        });
    }

    // Native DQN oracle over a deterministic pre-filled replay buffer
    // (episode-free, rebuildable from `seed` alone — which makes these
    // sessions suspend/adopt-able through the serve manifest, ISSUE 5).
    // The full episode-driven RL protocol stays under `optex rl`.
    if w == "dqn_replay" {
        let source = crate::rl::DqnSource::replay_fixture(seed);
        return Ok(Workload {
            source: Box::new(source),
            gp_artifact: None,
            name: "dqn_replay(native)".into(),
        });
    }

    // Same contract, real transitions: `dqn_<env>` replays a random
    // policy through the named env (acrobot/mountaincar/cartpole) into
    // the buffer, deterministically from `seed`.
    if let Some(env_name) = w.strip_prefix("dqn_") {
        let source = crate::rl::DqnSource::replay_fixture_env(env_name, seed)?;
        return Ok(Workload {
            source: Box::new(source),
            gp_artifact: None,
            name: format!("{w}(native)"),
        });
    }

    const MODEL_WORKLOADS: &[&str] =
        &["mnist", "fmnist", "cifar", "shakespeare", "tfm_char", "hp", "mlp_test"];
    if !MODEL_WORKLOADS.contains(&w) {
        bail!(
            "unknown workload {w:?} (synthetic: ackley|sphere|rosenbrock; \
             native dqn: dqn_replay|dqn_acrobot|dqn_mountaincar; \
             models: mnist|fmnist|cifar|shakespeare|hp; rl via `optex rl`)"
        );
    }
    // Model workloads need the manifest for shapes.
    let manifest = Manifest::load(&cfg.artifacts_dir)
        .context("model workloads require AOT artifacts")?;
    match w {
        "mnist" | "fmnist" => {
            let kind = ImageKind::parse(w).unwrap();
            let spec = manifest.get("mlp_mnist")?;
            let batch = spec.meta_usize("batch")?;
            let ds = ImageDataset::generate(kind, IMG_TRAIN_N, seed ^ 0xDA7A);
            let provider = MlpProvider::new(ds, batch, Rng::new(seed ^ 0xBA7C4));
            let source = HloSource::new(
                cfg.artifacts_dir.clone(),
                "mlp_mnist",
                n,
                Box::new(provider),
                0.0,
                seed,
            )?;
            Ok(Workload {
                source: Box::new(source),
                gp_artifact: Some("gp_mnist".into()),
                name: format!("{w}(mlp_mnist)"),
            })
        }
        "cifar" => {
            let spec = manifest.get("mlp_cifar")?;
            let batch = spec.meta_usize("batch")?;
            let ds = ImageDataset::generate(ImageKind::CifarLike, IMG_TRAIN_N, seed ^ 0xDA7A);
            let provider = MlpProvider::new(ds, batch, Rng::new(seed ^ 0xBA7C4));
            let source = HloSource::new(
                cfg.artifacts_dir.clone(),
                "mlp_cifar",
                n,
                Box::new(provider),
                0.0,
                seed,
            )?;
            Ok(Workload {
                source: Box::new(source),
                gp_artifact: Some("gp_cifar".into()),
                name: "cifar(mlp_cifar)".into(),
            })
        }
        "shakespeare" | "tfm_char" | "hp" => {
            let spec = manifest.get("tfm_char")?;
            let batch = spec.meta_usize("batch")?;
            let seq = spec.meta_usize("seq")?;
            let text = if w == "hp" {
                corpus::synthetic_narrative(seed ^ 0x40, 200_000)
            } else {
                corpus::shakespeare().to_string()
            };
            let provider =
                TfmProvider::new(Corpus::from_text(&text), batch, seq + 1, Rng::new(seed ^ 0x7F4));
            let source = HloSource::new(
                cfg.artifacts_dir.clone(),
                "tfm_char",
                n,
                Box::new(provider),
                0.0,
                seed,
            )?;
            Ok(Workload {
                source: Box::new(source),
                gp_artifact: Some("gp_tfm".into()),
                name: format!("{w}(tfm_char)"),
            })
        }
        // Test-profile artifacts, exercised by integration tests.
        "mlp_test" => {
            let spec = manifest.get("mlp_test")?;
            let batch = spec.meta_usize("batch")?;
            let in_dim = spec.meta_usize("in_dim")?;
            // mlp_test takes 16-dim inputs; reuse mnist-like pixels cropped.
            let ds = crop_dataset(
                ImageDataset::generate(ImageKind::MnistLike, 200, seed),
                in_dim,
                spec.meta_usize("out_dim")?,
            );
            let provider = MlpProvider::new(ds, batch, Rng::new(seed));
            let source = HloSource::new(
                cfg.artifacts_dir.clone(),
                "mlp_test",
                n,
                Box::new(provider),
                0.0,
                seed,
            )?;
            Ok(Workload {
                source: Box::new(source),
                gp_artifact: Some("gp_mlp_test".into()),
                name: "mlp_test".into(),
            })
        }
        other => unreachable!("filtered above: {other}"),
    }
}

/// Crop an image dataset to `in_dim` pixels / `classes` labels so the
/// tiny test-profile artifacts can be driven by real samplers.
fn crop_dataset(mut ds: ImageDataset, in_dim: usize, classes: usize) -> ImageDataset {
    let n = ds.len();
    let mut x = Vec::with_capacity(n * in_dim);
    for i in 0..n {
        x.extend_from_slice(&ds.image(i)[..in_dim]);
    }
    for y in &mut ds.y {
        *y %= classes as u8;
    }
    ds.x = x;
    ds.dim = in_dim;
    ds.n_classes = classes;
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn synthetic_native_builds_without_artifacts() {
        let mut cfg = RunConfig::default();
        cfg.workload = "sphere".into();
        cfg.synth_dim = 64;
        cfg.artifacts_dir = "/nonexistent".into();
        let w = build(&cfg).unwrap();
        assert_eq!(w.source.dim(), 64);
        assert_eq!(w.source.backend_name(), "native");
    }

    #[test]
    fn dqn_replay_builds_without_artifacts_and_matches_fixture() {
        let mut cfg = RunConfig::default();
        cfg.workload = "dqn_replay".into();
        cfg.seed = 7;
        cfg.artifacts_dir = "/nonexistent".into();
        let mut w = build(&cfg).unwrap();
        assert_eq!(w.source.backend_name(), "native");
        assert!(w.gp_artifact.is_none());
        // same oracle as the shared test fixture, bit-for-bit
        let mut fixture = crate::testutil::fixtures::dqn_replay_source(7);
        assert_eq!(w.source.dim(), fixture.dim());
        let p = vec![0.02f32; fixture.dim()];
        w.source.on_iteration(1, &p);
        fixture.on_iteration(1, &p);
        let (ea, ga) = w.source.eval_batch_owned(&[&p]).unwrap();
        let (eb, gb) = fixture.eval_batch_owned(&[&p]).unwrap();
        assert_eq!(ga, gb);
        assert_eq!(ea[0].loss.to_bits(), eb[0].loss.to_bits());
    }

    #[test]
    fn dqn_env_workloads_build_without_artifacts() {
        for (name, env_name) in [("dqn_acrobot", "acrobot"), ("dqn_mountaincar", "mountaincar")] {
            let mut cfg = RunConfig::default();
            cfg.workload = name.into();
            cfg.seed = 3;
            cfg.artifacts_dir = "/nonexistent".into();
            let w = build(&cfg).unwrap();
            assert_eq!(w.source.backend_name(), "native", "{name}");
            assert!(w.gp_artifact.is_none(), "{name}");
            let fixture = crate::rl::DqnSource::replay_fixture_env(env_name, 3).unwrap();
            assert_eq!(w.source.dim(), fixture.dim(), "{name}");
        }
        let mut cfg = RunConfig::default();
        cfg.workload = "dqn_pong".into();
        assert!(build(&cfg).is_err(), "unknown env must be a factory error");
    }

    #[test]
    fn unknown_workload_is_an_error() {
        let mut cfg = RunConfig::default();
        cfg.workload = "imagenet".into();
        let err = match build(&cfg) {
            Ok(_) => panic!("expected error"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("unknown workload"), "{err}");
    }

    #[test]
    fn model_workload_without_artifacts_fails_helpfully() {
        let mut cfg = RunConfig::default();
        cfg.workload = "mnist".into();
        cfg.artifacts_dir = "/nonexistent".into();
        let err = match build(&cfg) {
            Ok(_) => panic!("expected error"),
            Err(e) => format!("{e:#}"),
        };
        assert!(err.contains("artifacts"), "{err}");
    }

    #[test]
    fn crop_dataset_shapes() {
        let ds = ImageDataset::generate(ImageKind::MnistLike, 10, 0);
        let c = crop_dataset(ds, 16, 4);
        assert_eq!(c.dim, 16);
        assert_eq!(c.image(3).len(), 16);
        assert!(c.y.iter().all(|&y| y < 4));
    }
}
