//! Minimal JSON parser + writer.
//!
//! `serde_json` is not available offline, so this substrate implements the
//! subset of JSON the repo needs: the AOT `artifacts/manifest.json`
//! (objects, arrays, strings, numbers, bools, null) plus a writer used by
//! the metrics recorder. Strict enough to reject malformed input with a
//! positioned error; no trailing-comma or comment extensions.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch / missing key) -------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact). Strings are escaped; non-finite numbers -> null.
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // integers without trailing .0 for readability
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates are not paired here (manifest never
                            // contains them) — replace to stay lossless-safe.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "profile": "test",
          "artifacts": [
            {"name": "gp_test", "inputs": [{"shape": [4, 32], "dtype": "f32"}],
             "meta": {"t0": 4, "d": 64, "ok": true, "x": null}}
          ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("profile").unwrap().as_str(), Some("test"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let meta = arts[0].get("meta").unwrap();
        assert_eq!(meta.get("t0").unwrap().as_usize(), Some(4));
        assert_eq!(meta.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(meta.get("x"), Some(&Json::Null));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(32));
    }

    #[test]
    fn numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-3", -3.0),
            ("2.5", 2.5),
            ("1e3", 1000.0),
            ("-1.5E-2", -0.015),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{7}".to_string());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let doc = r#"{"a":[1,2,{"b":[true,false,null]}],"c":-1.25e2}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
