//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate is available offline, so this is a from-scratch
//! substrate: SplitMix64 for seeding, xoshiro256++ for the stream, polar
//! Box–Muller for normals. Every stochastic component in the repo
//! (datasets, noise injection, subset sampling, RL exploration) threads a
//! [`Rng`] explicitly so runs are reproducible from a single seed.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the polar transform.
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create from a 64-bit seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-worker / per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Snapshot the full generator state — the xoshiro words plus the
    /// cached Box–Muller spare — for checkpoint-backed resume. Restoring
    /// via [`Rng::from_state`] continues the stream bit-identically,
    /// which is what lets stochastic oracles survive suspend/adopt
    /// without replaying their noise/minibatch history (ISSUE 5).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Rng {
        Rng { s, spare }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free multiply-shift is fine at our scales.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via the polar (Marsaglia) method.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Vector of standard normals as f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fill a slice with scaled normals.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for x in out.iter_mut() {
            *x = self.normal() as f32 * scale;
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    /// Returned indices are in shuffled order. Panics if k > n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        // For small k relative to n, use a hash-free swap table on a range.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::new(9);
        let k = 50;
        let idx = r.sample_indices(1000, k);
        assert_eq!(idx.len(), k);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), k, "indices must be distinct");
        assert!(sorted.iter().all(|&i| i < 1000));
    }

    #[test]
    fn sample_indices_full_permutation() {
        let mut r = Rng::new(5);
        let mut idx = r.sample_indices(10, 10);
        idx.sort_unstable();
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(11);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_bit_identically() {
        let mut r = Rng::new(13);
        // advance into an odd phase: normal() leaves a cached spare
        for _ in 0..7 {
            r.normal();
        }
        let (s, spare) = r.state();
        let mut back = Rng::from_state(s, spare);
        for _ in 0..100 {
            assert_eq!(r.next_u64(), back.next_u64());
        }
        // the spare itself must survive (first normal after restore)
        let mut a = Rng::new(21);
        a.normal();
        let (s, spare) = a.state();
        assert!(spare.is_some(), "odd normal draw caches a spare");
        let mut b = Rng::from_state(s, spare);
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(2);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut back = xs.clone();
        back.sort_unstable();
        assert_eq!(back, (0..100).collect::<Vec<_>>());
    }
}
