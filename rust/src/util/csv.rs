//! Tiny CSV writer for figure series and metrics logs.
//!
//! Only what the figure harness needs: header + homogeneous numeric rows
//! with an optional leading string column. Values are written with enough
//! precision to round-trip f64 through plotting tools.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Append-only CSV file writer.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (truncating) a CSV at `path`, writing the header row.
    /// Parent directories are created as needed.
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write one numeric row. Panics (debug) if the arity mismatches.
    pub fn row(&mut self, values: &[f64]) -> std::io::Result<()> {
        debug_assert_eq!(values.len(), self.cols, "csv arity mismatch");
        let mut line = String::with_capacity(values.len() * 12);
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&format_num(*v));
        }
        writeln!(self.out, "{line}")
    }

    /// Write a row with a leading tag column (series label).
    pub fn tagged_row(&mut self, tag: &str, values: &[f64]) -> std::io::Result<()> {
        debug_assert_eq!(values.len() + 1, self.cols, "csv arity mismatch");
        let mut line = String::with_capacity(16 + values.len() * 12);
        line.push_str(tag);
        for v in values {
            line.push(',');
            line.push_str(&format_num(*v));
        }
        writeln!(self.out, "{line}")
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn format_num(v: f64) -> String {
    if v.is_finite() && v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("optex_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["method", "iter", "loss"]).unwrap();
            w.tagged_row("optex", &[1.0, 0.5]).unwrap();
            w.tagged_row("vanilla", &[2.0, 0.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "method,iter,loss");
        assert!(lines[1].starts_with("optex,1,"));
        assert_eq!(lines.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn numeric_formatting() {
        assert_eq!(format_num(3.0), "3");
        assert!(format_num(0.5).contains('e'));
        assert!(format_num(f64::NAN).contains("NaN") || !format_num(f64::NAN).is_empty());
    }
}
