//! Lightweight phase timers for the coordinator hot loop and the bench
//! harness. Accumulates per-label durations with zero allocation after
//! the first occurrence of a label.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulating multi-phase timer.
#[derive(Debug, Default)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, (Duration, u64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `label`.
    pub fn time<T>(&mut self, label: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed());
        out
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, label: &'static str, d: Duration) {
        let e = self.acc.entry(label).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Total time under a label.
    pub fn total(&self, label: &str) -> Duration {
        self.acc.get(label).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    /// Call count under a label.
    pub fn count(&self, label: &str) -> u64 {
        self.acc.get(label).map(|e| e.1).unwrap_or(0)
    }

    /// Human-readable summary sorted by total time, descending.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.acc.iter().collect();
        rows.sort_by(|a, b| b.1 .0.cmp(&a.1 .0));
        let mut s = String::new();
        for (label, (d, n)) in rows {
            s.push_str(&format!(
                "{label:24} {:>10.3}s  x{n:<7} {:>9.3}ms/call\n",
                d.as_secs_f64(),
                d.as_secs_f64() * 1e3 / (*n).max(1) as f64
            ));
        }
        s
    }

    pub fn labels(&self) -> impl Iterator<Item = &'static str> + '_ {
        self.acc.keys().copied()
    }
}

/// One-shot stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_labels() {
        let mut t = PhaseTimer::new();
        let v = t.time("a", || 7);
        assert_eq!(v, 7);
        t.time("a", || ());
        t.add("b", Duration::from_millis(5));
        assert_eq!(t.count("a"), 2);
        assert_eq!(t.count("b"), 1);
        assert!(t.total("b") >= Duration::from_millis(5));
        assert_eq!(t.count("missing"), 0);
        let rep = t.report();
        assert!(rep.contains('a') && rep.contains('b'));
    }

    #[test]
    fn stopwatch_monotone() {
        let s = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(s.secs() > 0.0);
    }

    // -- ISSUE 9 satellite: full-surface coverage ------------------------

    #[test]
    fn time_returns_closure_value_and_accumulates_duration() {
        let mut t = PhaseTimer::new();
        let out = t.time("phase", || {
            std::thread::sleep(Duration::from_millis(2));
            "done"
        });
        assert_eq!(out, "done");
        assert!(t.total("phase") >= Duration::from_millis(2));
        assert_eq!(t.count("phase"), 1);
        // totals accumulate across calls, they never overwrite
        let before = t.total("phase");
        t.add("phase", Duration::from_millis(3));
        assert_eq!(t.total("phase"), before + Duration::from_millis(3));
        assert_eq!(t.count("phase"), 2);
    }

    #[test]
    fn report_sorts_by_total_time_descending_with_call_counts() {
        let mut t = PhaseTimer::new();
        t.add("cheap", Duration::from_millis(1));
        t.add("costly", Duration::from_millis(50));
        t.add("costly", Duration::from_millis(50));
        let rep = t.report();
        let costly = rep.find("costly").expect("costly row missing");
        let cheap = rep.find("cheap").expect("cheap row missing");
        assert!(costly < cheap, "report not sorted by total time:\n{rep}");
        assert!(rep.contains("x2"), "call count missing from report:\n{rep}");
        // labels() walks the accumulator keys (BTreeMap = sorted order)
        let labels: Vec<_> = t.labels().collect();
        assert_eq!(labels, vec!["cheap", "costly"]);
    }

    #[test]
    fn empty_timer_reports_nothing_and_default_stopwatch_runs() {
        let t = PhaseTimer::new();
        assert!(t.report().is_empty());
        assert_eq!(t.labels().count(), 0);
        assert_eq!(t.total("anything"), Duration::ZERO);
        let s = Stopwatch::default();
        assert!(s.elapsed() >= Duration::ZERO);
        assert!(s.secs() >= 0.0);
    }
}
