//! Small statistics toolbox used by the metrics recorder, the bench
//! harness and the figure generators (no external stats crates offline).

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation; 0 for < 2 samples.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copies.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// L2 norm of an f32 slice, accumulated in f64.
pub fn norm2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Dot product of two f32 slices in f64.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Mean of per-run series with ragged lengths: returns the element-wise
/// mean truncated at the shortest series (used to average seeds).
pub fn mean_series(series: &[Vec<f64>]) -> Vec<f64> {
    if series.is_empty() {
        return Vec::new();
    }
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    (0..n)
        .map(|i| series.iter().map(|s| s[i]).sum::<f64>() / series.len() as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 16.0);
        assert_eq!(o.count(), 5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[4.0, 1.0, 2.0, 3.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-9);
    }

    #[test]
    fn mean_series_truncates() {
        let s = vec![vec![1.0, 2.0, 3.0], vec![3.0, 4.0]];
        assert_eq!(mean_series(&s), vec![2.0, 3.0]);
        assert!(mean_series(&[]).is_empty());
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
