//! Cross-cutting utility substrates (all built from scratch — the offline
//! crate set has no rand / serde_json / csv / timing helpers).

pub mod b64;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
