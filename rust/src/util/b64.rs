//! Minimal base64 (RFC 4648, standard alphabet, padded) — the offline
//! crate set has no encoder, and the serve tier's `export`/`import`
//! verbs need to carry raw checkpoint bytes inside a JSONL line.
//!
//! Size discipline: base64 inflates by 4/3, and import requests ride
//! the serve tier's 1 MiB request-line cap — callers migrating very
//! large sessions hit that bound, which `docs/PROTOCOL.md` documents as
//! the import payload limit.

const ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode `data` as padded standard base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len() * 4 / 3 + 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let n = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(n >> 18) as usize & 0x3f] as char);
        out.push(ALPHABET[(n >> 12) as usize & 0x3f] as char);
        out.push(if chunk.len() > 1 {
            ALPHABET[(n >> 6) as usize & 0x3f] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 { ALPHABET[n as usize & 0x3f] as char } else { '=' });
    }
    out
}

fn decode_sym(c: u8) -> Result<u32, String> {
    match c {
        b'A'..=b'Z' => Ok((c - b'A') as u32),
        b'a'..=b'z' => Ok((c - b'a') as u32 + 26),
        b'0'..=b'9' => Ok((c - b'0') as u32 + 52),
        b'+' => Ok(62),
        b'/' => Ok(63),
        other => Err(format!("invalid base64 byte 0x{other:02x}")),
    }
}

/// Decode padded standard base64. Rejects whitespace, wrong padding and
/// out-of-alphabet bytes (wire payloads are machine-built; leniency
/// would only mask corruption).
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(format!("base64 length {} not a multiple of 4", bytes.len()));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for (i, quad) in bytes.chunks_exact(4).enumerate() {
        let last = (i + 1) * 4 == bytes.len();
        let pad = quad.iter().filter(|&&c| c == b'=').count();
        if pad > 0 && (!last || quad[..4 - pad].contains(&b'=') || pad > 2) {
            return Err("misplaced base64 padding".into());
        }
        let mut n = 0u32;
        for &c in &quad[..4 - pad] {
            n = (n << 6) | decode_sym(c)?;
        }
        n <<= 6 * pad as u32;
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn binary_round_trip() {
        // checkpoint-like payload: every byte value, awkward lengths
        for len in [0usize, 1, 2, 3, 255, 256, 257, 1000] {
            let data: Vec<u8> = (0..len).map(|i| (i * 7 % 256) as u8).collect();
            let enc = encode(&data);
            assert_eq!(decode(&enc).unwrap(), data, "len {len}");
        }
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["Zg=", "Zg===", "Z===", "=Zg=", "Zg==Zg==", "Zm 9v", "Zm\n9v", "Zm9v!"] {
            assert!(decode(bad).is_err(), "{bad:?} should be rejected");
        }
        // '=' only valid as trailing padding of the final quad
        assert!(decode("Zg==Zm9v").is_err());
    }
}
