//! `optex` — launcher for the OptEx reproduction.
//!
//! Subcommands:
//!   run        one optimization run from a TOML config (+ --set overrides)
//!   serve      multi-session serving: concurrent runs over one compute
//!              pool, driven by a JSONL wire protocol (ISSUE 4)
//!   router     multi-process scale-out: N serve workers behind one
//!              endpoint, with live session migration (ISSUE 10)
//!   fig <id>   regenerate a paper figure (2, 3, 4a, 4b, 6, 7–10, ...)
//!   rl         DQN training on a classic-control env
//!   artifacts  inspect the AOT artifact manifest
//!   scenarios  run the declarative scenario corpus against its goldens
//!   help       this text

use std::path::PathBuf;
use std::process::ExitCode;

use optex::cli::Args;
use optex::config::RunConfig;

use optex::figures::{self, FigOpts};
use optex::rl::dqn::{self, RlConfig};
use optex::runtime::Manifest;

const HELP: &str = "\
optex — OptEx: first-order optimization with approximately parallelized iterations

USAGE:
  optex run  [--config FILE] [--workload W] [--method M] [--steps T]
             [--seed S] [--fit full|incremental] [--threads K]
             [--pool scoped|persistent] [--gp-refresh-every K]
             [--checkpoint FILE] [--resume FILE]
             [--faults SPEC]          # deterministic fault plan; see faults/ docs
             [--set key=value ...]
  optex serve [--config FILE] [--addr HOST:PORT] [--max-sessions K]
              [--threads K] [--pool scoped|persistent] [--policy rr|fair]
              [--steppers S]          # concurrent quanta (stepper pool width)
              [--metrics-addr HOST:PORT]  # Prometheus exposition listener
              [--adopt]               # adopt serve.ckpt_dir's session manifest
              [--faults SPEC]         # injected into sessions by (s,i,p) key
              [--set key=value ...]   # JSONL protocol; see serve/ docs
  optex router [--config FILE] [--addr HOST:PORT] [--workers N]
               [--dir DIR]            # router state + worker dirs (default results/router)
               [--worker-bin PATH]    # optex binary for workers (default: self)
               [--set key=value ...]  # base config forwarded to every worker;
                                      # same wire protocol + `migrate`; docs/PROTOCOL.md
  optex fig  <2|3|4a|4b|6|6a..6d|7|8|9|10|kernels|estbound|nativehlo|all>
             [--seeds K] [--steps T] [--quick] [--out DIR] [--artifacts DIR]
  optex rl   --env <cartpole|mountaincar|acrobot> [--episodes E]
             [--method M] [--set key=value ...]
  optex artifacts [--artifacts DIR]
  optex validate  [--artifacts DIR]   # health check: artifacts vs native
  optex scenarios [--dir DIR] [--filter SUBSTR] [--threads K] [--steppers S]
                  [--bless]
                  # golden-trajectory corpus (scenarios/ by default);
                  # --bless rewrites stale/missing goldens; --steppers S
                  # replays serve scenarios on an S-wide stepper pool
                  # (goldens must not change — that's the point)

Methods: optex | vanilla | target | dataparallel.
Config keys: see configs/*.toml and `RunConfig` docs.
";

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    if args.flag("help") || args.subcommand.is_none() {
        print!("{HELP}");
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "router" => cmd_router(&args),
        "fig" => cmd_fig(&args),
        "rl" => cmd_rl(&args),
        "artifacts" => cmd_artifacts(&args),
        "validate" => cmd_validate(&args),
        "scenarios" => cmd_scenarios(&args),
        "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}; see `optex help`"),
    }
}

fn load_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::from_toml(&std::fs::read_to_string(path)?)?,
        None => RunConfig::default(),
    };
    if let Some(w) = args.opt("workload") {
        cfg.apply_override(&format!("workload={w}"))?;
    }
    if let Some(m) = args.opt("method") {
        cfg.apply_override(&format!("method={m}"))?;
    }
    if let Some(t) = args.opt_usize("steps")? {
        cfg.apply_override(&format!("steps={t}"))?;
    }
    if let Some(s) = args.opt_usize("seed")? {
        cfg.apply_override(&format!("seed={s}"))?;
    }
    if let Some(o) = args.opt("optimizer") {
        cfg.apply_override(&format!("optimizer.name={o}"))?;
    }
    if let Some(lr) = args.opt_f64("lr")? {
        cfg.apply_override(&format!("optimizer.lr={lr}"))?;
    }
    if let Some(n) = args.opt_usize("n")? {
        cfg.apply_override(&format!("optex.parallelism={n}"))?;
    }
    if let Some(t0) = args.opt_usize("t0")? {
        cfg.apply_override(&format!("optex.t0={t0}"))?;
    }
    if let Some(d) = args.opt_usize("dim")? {
        cfg.apply_override(&format!("synth_dim={d}"))?;
    }
    if let Some(b) = args.opt("backend") {
        cfg.apply_override(&format!("optex.backend={b}"))?;
    }
    if let Some(f) = args.opt("fit") {
        cfg.apply_override(&format!("optex.fit={f}"))?;
    }
    if let Some(k) = args.opt_usize("threads")? {
        cfg.apply_override(&format!("optex.threads={k}"))?;
    }
    if let Some(p) = args.opt("pool") {
        cfg.apply_override(&format!("optex.pool={p}"))?;
    }
    if let Some(k) = args.opt_usize("gp-refresh-every")? {
        cfg.apply_override(&format!("optex.gp_refresh_every={k}"))?;
    }
    if let Some(f) = args.opt("faults") {
        // quoted: a bare fault spec would be re-typed by the override
        // value grammar at the first `:` argument
        cfg.apply_override(&format!("faults={:?}", f))?;
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    if let Some(o) = args.opt("out") {
        cfg.out_dir = PathBuf::from(o);
    }
    for kv in &args.sets {
        cfg.apply_override(kv)?;
    }
    Ok(cfg)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help", "hlo"])?;
    let mut cfg = load_config(args)?;
    if args.flag("hlo") {
        cfg.hlo_workload = true;
    }
    println!("config: {:?}", cfg.describe());
    let workload = optex::workloads::factory::build(&cfg)?;
    let mut drv = optex::coordinator::Driver::new(cfg.clone(), workload)?;
    let start = match args.opt("resume") {
        Some(path) => {
            let it = drv.resume_from(std::path::Path::new(path))?;
            println!("resumed from {path} at iteration {it}");
            it as usize
        }
        None => 0,
    };
    for t in start + 1..=start + cfg.steps {
        drv.iteration(t)?;
    }
    if let Some(path) = args.opt("checkpoint") {
        drv.save_checkpoint(std::path::Path::new(path), (start + cfg.steps) as u64)?;
        println!("checkpointed to {path}");
    }
    let record = drv.record().clone();
    println!("{}", record.summary());
    let path = cfg.out_dir.join(format!(
        "run_{}_{}_{}.csv",
        cfg.workload,
        cfg.method.name(),
        cfg.seed
    ));
    record.to_csv(&path)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Multi-session serving: bind the JSONL endpoint and run the scheduler
/// loop until a `shutdown` command arrives. The loaded config is the
/// BASE every submitted session starts from (its `config` object is
/// applied on top as `--set`-style overrides).
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help", "adopt"])?;
    let mut cfg = load_config(args)?;
    if let Some(a) = args.opt("addr") {
        cfg.apply_override(&format!("serve.addr={a}"))?;
    }
    if let Some(k) = args.opt_usize("max-sessions")? {
        cfg.apply_override(&format!("serve.max_sessions={k}"))?;
    }
    if let Some(p) = args.opt("policy") {
        cfg.apply_override(&format!("serve.policy={p}"))?;
    }
    if let Some(s) = args.opt_usize("steppers")? {
        cfg.apply_override(&format!("serve.steppers={s}"))?;
    }
    if let Some(m) = args.opt("metrics-addr") {
        cfg.apply_override(&format!("serve.metrics_addr={m}"))?;
    }
    if args.flag("adopt") {
        cfg.apply_override("serve.adopt=true")?;
    }
    optex::serve::serve(&cfg)
}

/// Multi-process scale-out (ISSUE 10): spawn `router.workers` real
/// `optex serve` child processes and front them with one endpoint that
/// speaks the same protocol plus `migrate`. The loaded config is the
/// base config of every worker.
fn cmd_router(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help"])?;
    let mut cfg = load_config(args)?;
    if let Some(a) = args.opt("addr") {
        cfg.apply_override(&format!("router.addr={a}"))?;
    }
    if let Some(n) = args.opt_usize("workers")? {
        anyhow::ensure!(n >= 1, "--workers: must be >= 1");
        cfg.apply_override(&format!("router.workers={n}"))?;
    }
    if let Some(d) = args.opt("dir") {
        cfg.apply_override(&format!("router.dir={d}"))?;
    }
    if let Some(b) = args.opt("worker-bin") {
        cfg.apply_override(&format!("router.worker_bin={b}"))?;
    }
    if let Some(k) = args.opt_usize("max-sessions")? {
        // per-worker cap, forwarded with the rest of the base config
        cfg.apply_override(&format!("serve.max_sessions={k}"))?;
    }
    optex::router::router(&cfg)
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help", "quick"])?;
    let id = args
        .opt("fig")
        .map(str::to_string)
        .or_else(|| args.positionals.first().cloned())
        .ok_or_else(|| anyhow::anyhow!("fig: which figure? e.g. `optex fig 2`"))?;
    let mut opts = FigOpts::default();
    if let Some(s) = args.opt_usize("seeds")? {
        opts.seeds = s.max(1);
    }
    if let Some(t) = args.opt_usize("steps")? {
        opts.steps = Some(t);
    }
    opts.quick = args.flag("quick");
    if let Some(o) = args.opt("out") {
        opts.out_dir = PathBuf::from(o);
    }
    if let Some(a) = args.opt("artifacts") {
        opts.artifacts_dir = PathBuf::from(a);
    }
    figures::run(&id, &opts)
}

fn cmd_rl(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help", "hlo"])?;
    let env = args.opt("env").unwrap_or("cartpole").to_string();
    let mut cfg = load_config(args)?;
    cfg.workload = env.clone();
    if args.flag("hlo") {
        cfg.hlo_workload = true;
    }
    let mut rl = RlConfig::paper(&env);
    if let Some(e) = args.opt_usize("episodes")? {
        rl.episodes = e;
    }
    let record = dqn::train(&cfg, &rl)?;
    println!("{}", record.summary());
    let last = record.rows.last().map(|r| r.aux.unwrap_or(f64::NAN));
    println!("final cumulative avg reward: {last:?}");
    let path = cfg
        .out_dir
        .join(format!("rl_{env}_{}_{}.csv", cfg.method.name(), cfg.seed));
    record.to_csv(&path)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Deployment health check: every gp_estimate artifact loads, executes,
/// and agrees with the native estimator; one workload artifact per family
/// round-trips. Exit code reflects the outcome.
fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help"])?;
    let dir = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
    let mut opts = FigOpts::default();
    opts.artifacts_dir = dir.clone();
    opts.out_dir = std::env::temp_dir().join("optex_validate");
    println!("validating artifacts at {}", dir.display());
    figures::fig_ext::run_native_vs_hlo(&opts)?;
    println!("validate: OK");
    Ok(())
}

/// Golden-trajectory corpus runner (ISSUE 6): execute every scenario
/// file under `--dir`, check its declared invariants, and byte-compare
/// the trajectory render against the committed `.golden`. `--bless`
/// rewrites stale or missing goldens (sqllogictest-style).
fn cmd_scenarios(args: &Args) -> anyhow::Result<()> {
    use optex::scenarios::{run_corpus, BlessMode, Opts, Status};
    args.check_known_flags(&["help", "bless"])?;
    let mut opts = Opts::new(PathBuf::from(args.opt("dir").unwrap_or("scenarios")));
    opts.filter = args.opt("filter").map(str::to_string);
    if let Some(k) = args.opt_usize("threads")? {
        opts.threads = k;
    }
    if let Some(s) = args.opt_usize("steppers")? {
        anyhow::ensure!(s >= 1, "--steppers: must be >= 1");
        opts.steppers = s;
    }
    if args.flag("bless") {
        opts.bless = BlessMode::All;
    }
    let report = run_corpus(&opts)?;
    for r in &report.results {
        if r.detail.is_empty() {
            println!("{:7} {}", r.status.name(), r.name);
        } else {
            println!("{:7} {}  {}", r.status.name(), r.name, r.detail);
        }
    }
    println!("{}", report.summary());
    if report.failed() {
        anyhow::bail!(
            "scenario corpus failed ({} diff, {} missing, {} error)",
            report.count(Status::Diff),
            report.count(Status::Missing),
            report.count(Status::Error)
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    args.check_known_flags(&["help"])?;
    let dir = PathBuf::from(args.opt("artifacts").unwrap_or("artifacts"));
    let m = Manifest::load(&dir)?;
    println!("profile: {} ({} artifacts) at {}", m.profile, m.len(), dir.display());
    for name in m.names() {
        let a = m.get(name)?;
        let d = a.dim().unwrap_or(0);
        println!(
            "  {name:28} family={:12} d={d:<9} inputs={}",
            a.family().unwrap_or("?"),
            a.inputs.len()
        );
    }
    Ok(())
}
