//! Prometheus-style text exposition of a registry snapshot, plus the
//! second-listener scrape endpoint behind `serve.metrics_addr`.
//!
//! The responder is deliberately minimal: any HTTP/1.x request on the
//! metrics listener gets a `200 OK` with the full exposition —
//! text format version 0.0.4, `# TYPE` lines included, histograms
//! rendered as cumulative `_bucket{le=...}` series plus `_sum`/`_count`
//! (the log2 buckets' inclusive upper bounds are `2^b - 1`; see
//! `registry::bucket_le`). No routing, no keep-alive, no external deps
//! — a scraper (or `curl`) reads one response and the connection
//! closes.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

use anyhow::{Context, Result};

use crate::obs::registry::{bucket_le, Registry, Snapshot};

/// Render a snapshot in the Prometheus text exposition format.
pub fn render(snap: &Snapshot) -> String {
    let mut out = String::new();
    for &(name, v) in &snap.counters {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for &(name, v) in &snap.gauges {
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for h in &snap.hists {
        let name = h.name;
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (b, &n) in h.buckets.iter().enumerate() {
            cum += n;
            if n == 0 {
                // skip interior zero-delta buckets to keep the page
                // readable; cumulative correctness is unaffected
                continue;
            }
            if let Some(le) = bucket_le(b) {
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

fn answer(mut stream: TcpStream, registry: &Registry) {
    // Drain (up to 4 KiB of) the request so the client's write never
    // sees a reset, then respond to anything with the exposition.
    let mut buf = [0u8; 4096];
    let _ = stream.read(&mut buf);
    let body = render(&registry.snapshot());
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|_| stream.write_all(body.as_bytes()))
        .and_then(|_| stream.flush());
}

/// Bind `addr` and serve the exposition from a detached thread for the
/// life of the process. Returns the bound address (port 0 resolves to
/// the ephemeral port). The thread holds only a registry handle — it
/// never touches the scheduler, so a slow scraper cannot stall a
/// quantum.
pub fn spawn_metrics_listener(addr: &str, registry: Registry) -> Result<SocketAddr> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("binding serve.metrics_addr {addr:?}"))?;
    let bound = listener.local_addr()?;
    std::thread::Builder::new()
        .name("optex-metrics".into())
        .spawn(move || {
            for stream in listener.incoming().flatten() {
                answer(stream, &registry);
            }
        })
        .context("spawning metrics listener thread")?;
    Ok(bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{Counter, Gauge, Hist};

    #[test]
    fn render_covers_every_metric_with_type_lines() {
        let reg = Registry::new();
        let text = render(&reg.snapshot());
        for c in Counter::ALL {
            assert!(text.contains(&format!("# TYPE {} counter", c.name())), "{}", c.name());
        }
        for g in Gauge::ALL {
            assert!(text.contains(&format!("# TYPE {} gauge", g.name())), "{}", g.name());
        }
        for h in Hist::ALL {
            assert!(
                text.contains(&format!("# TYPE {} histogram", h.name())),
                "{}",
                h.name()
            );
            assert!(text.contains(&format!("{}_count", h.name())));
        }
    }

    #[cfg(feature = "obs")]
    #[test]
    fn histogram_buckets_render_cumulative() {
        let reg = Registry::new();
        reg.observe(Hist::GrantWidth, 1); // bucket 1, le="1"
        reg.observe(Hist::GrantWidth, 2); // bucket 2, le="3"
        reg.observe(Hist::GrantWidth, 3); // bucket 2
        let text = render(&reg.snapshot());
        assert!(text.contains("optex_grant_width_bucket{le=\"1\"} 1\n"), "{text}");
        assert!(text.contains("optex_grant_width_bucket{le=\"3\"} 3\n"), "{text}");
        assert!(text.contains("optex_grant_width_bucket{le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("optex_grant_width_sum 6\n"), "{text}");
        assert!(text.contains("optex_grant_width_count 3\n"), "{text}");
    }

    #[cfg(feature = "obs")]
    #[test]
    fn listener_answers_http_with_the_exposition() {
        let reg = Registry::new();
        reg.incr(Counter::Iterations);
        reg.gauge_set(Gauge::Steppers, 4);
        let addr = spawn_metrics_listener("127.0.0.1:0", reg.clone()).unwrap();
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        let body = resp.split("\r\n\r\n").nth(1).expect("header/body split");
        assert!(body.contains("optex_iterations_total 1\n"), "{body}");
        assert!(body.contains("optex_steppers 4\n"), "{body}");
        // every non-comment line is `name{labels}? value`
        for line in body.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(name.starts_with("optex_"), "{line}");
            value.parse::<u64>().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        }
    }
}
