//! Observability (ISSUE 9): a zero-dependency metrics + flight-recorder
//! subsystem threaded through the driver, the GP engines, the serve
//! scheduler/arbiter/stepper pool, the fault layer and the server.
//!
//! Three pieces:
//!
//! * [`Registry`] — counters, gauges and fixed-bucket log2 histograms
//!   behind a cloneable handle. The hot path is zero-alloc: counter and
//!   histogram writes land in per-thread shards (plain relaxed atomics,
//!   no locks) merged only when a snapshot is taken. A disabled handle
//!   ([`Registry::disabled`]) is a single `Option` branch per call —
//!   and with the `obs` cargo feature off every method compiles to a
//!   no-op, which is what the bench harness' obs-overhead cell compares
//!   against.
//! * [`FlightRecorder`] — a bounded ring of sequence-numbered,
//!   phase-tagged events per session (begin_quantum, grant, retry,
//!   fault fired, nonfinite resync, quarantine, ...). Renders are
//!   deterministic: sequence numbers and iteration indices only, never
//!   wall-clock — so trace output can be asserted byte-for-byte and can
//!   never leak nondeterminism into scenario goldens (the golden
//!   renderer consumes `Outcome` alone and ignores obs entirely).
//! * [`expo`] — Prometheus-style text exposition of a registry
//!   snapshot, served over a second listener (`serve.metrics_addr` /
//!   `optex serve --metrics-addr`) by a minimal HTTP/1.0 responder.
//!
//! Wire access: the serve protocol gained `stats` (server-wide registry
//! snapshot) and `trace` (one session's ring dump) verbs — see
//! `serve/protocol.rs`.

pub mod expo;
pub mod recorder;
pub mod registry;

pub use recorder::{FlightRecorder, ObsEvent, TracePhase};
pub use registry::{Counter, Gauge, Hist, HistSnapshot, Registry, Snapshot};

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Rate-limited stderr reporter for burst events (connection sheds,
/// oversized-line rejections): at most one line per `period`, with a
/// count of how many occurrences the quiet window absorbed. Wall-clock
/// is fine here — stderr is operator output and never reaches goldens.
pub struct BurstLog {
    period: Duration,
    state: Mutex<BurstState>,
}

struct BurstState {
    last_emit: Option<Instant>,
    suppressed: u64,
}

impl BurstLog {
    pub fn new(period: Duration) -> BurstLog {
        BurstLog {
            period,
            state: Mutex::new(BurstState { last_emit: None, suppressed: 0 }),
        }
    }

    /// Report one occurrence. Emits `msg` (plus a suppressed-count tail
    /// when the window absorbed earlier occurrences) at most once per
    /// period; otherwise just counts.
    pub fn note(&self, msg: &str) {
        let Ok(mut st) = self.state.lock() else { return };
        let now = Instant::now();
        let due = match st.last_emit {
            None => true,
            Some(t) => now.duration_since(t) >= self.period,
        };
        if due {
            if st.suppressed > 0 {
                eprintln!("{msg} ({} earlier in this burst suppressed)", st.suppressed);
            } else {
                eprintln!("{msg}");
            }
            st.last_emit = Some(now);
            st.suppressed = 0;
        } else {
            st.suppressed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_log_counts_suppressed_occurrences() {
        // behavioural floor only (output goes to stderr): the state
        // machine must count while quiet and reset on emit
        let log = BurstLog::new(Duration::from_secs(3600));
        log.note("first");
        for _ in 0..5 {
            log.note("suppressed");
        }
        let st = log.state.lock().unwrap();
        assert_eq!(st.suppressed, 5);
        assert!(st.last_emit.is_some());
    }
}
