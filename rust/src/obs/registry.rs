//! The metrics registry: counters, gauges and log2 histograms behind a
//! cloneable zero-alloc handle.
//!
//! ## Hot-path design
//!
//! Counter and histogram writes go to one of [`SHARDS`] per-thread
//! shards, picked by a thread-local index assigned at first use —
//! every write is a single relaxed `fetch_add` on a slot no other
//! *writing* thread touches (two threads may share a shard once more
//! than `SHARDS` threads exist; atomics keep that correct, it only
//! costs a cache line). Reads ([`Registry::snapshot`]) merge the shards
//! by summation. Gauges are last-write-wins set operations and are not
//! sharded.
//!
//! ## Histograms
//!
//! Fixed log2 bucketing: value `v` lands in bucket
//! `64 - v.leading_zeros()` (0 stays in bucket 0), clamped to
//! [`BUCKETS`] - 1 — so bucket `b >= 1` covers `[2^(b-1), 2^b)` and the
//! exposition's `le` labels are `2^b - 1`. No float math, no config,
//! no allocation.
//!
//! ## Compile-out
//!
//! With the `obs` cargo feature off (it is on by default) the handle
//! holds no state and every method body is empty — the call sites stay
//! compiled and type-checked, the instrumentation itself vanishes. The
//! bench harness' obs-overhead cell measures the runtime analogue
//! (a [`Registry::disabled`] handle: one `Option` branch per call).

#[cfg(feature = "obs")]
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
#[cfg(feature = "obs")]
use std::sync::Arc;

/// Number of write shards. More than enough for the serve tier's
/// thread count (serve thread + steppers + pool workers); beyond it,
/// threads share shards correctly.
pub const SHARDS: usize = 16;

/// Histogram bucket count (log2 buckets; values clamp into the last).
pub const BUCKETS: usize = 32;

macro_rules! metric_enum {
    ($(#[$doc:meta])* $name:ident { $($(#[$vdoc:meta])* $variant:ident => $label:literal,)+ }) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        #[repr(usize)]
        pub enum $name {
            $($(#[$vdoc])* $variant,)+
        }

        impl $name {
            /// Every variant, in declaration (= exposition) order.
            pub const ALL: &'static [$name] = &[$($name::$variant,)+];

            /// Number of variants (array sizing).
            pub const COUNT: usize = $name::ALL.len();

            /// The exposition metric name.
            pub fn name(self) -> &'static str {
                match self {
                    $($name::$variant => $label,)+
                }
            }
        }
    };
}

metric_enum! {
    /// Monotone counters (exposition type `counter`).
    Counter {
        /// Completed driver iterations (every session, every method).
        Iterations => "optex_iterations_total",
        /// Eval fan-out attempts retried under `optex.retry_max`.
        Retries => "optex_retries_total",
        /// Non-finite eval points absorbed by `optex.on_nonfinite`.
        Nonfinite => "optex_nonfinite_total",
        /// Full GP refits forced by ring restructuring / NotSpd.
        GpRebuilds => "optex_gp_rebuilds_total",
        /// Rank-1 Cholesky factor edits by the incremental GP fit.
        GpFactorOps => "optex_gp_factor_ops_total",
        /// Quanta dispatched by the serve scheduler.
        Quanta => "optex_quanta_total",
        /// Injected faults that actually fired (any site).
        FaultsFired => "optex_faults_fired_total",
        /// Sessions admitted by the scheduler.
        SessionsSubmitted => "optex_sessions_submitted_total",
        /// Sessions quarantined after a caught panic.
        SessionsQuarantined => "optex_sessions_quarantined_total",
        /// Durable manifest rewrites.
        ManifestRewrites => "optex_manifest_rewrites_total",
        /// `watch` records pushed (iter + terminal).
        WatchPushes => "optex_watch_pushes_total",
        /// Connections shed at the `serve.max_conns` cap.
        ConnSheds => "optex_conn_sheds_total",
        /// Request lines rejected for exceeding the line cap.
        LineRejects => "optex_line_rejects_total",
    }
}

metric_enum! {
    /// Last-write-wins gauges (exposition type `gauge`).
    Gauge {
        /// Threads currently granted to in-flight quanta.
        ArbiterInUse => "optex_arbiter_in_use",
        /// The server's physical pool width.
        ArbiterPhysical => "optex_arbiter_physical",
        /// Stepper-pool width (`serve.steppers`).
        Steppers => "optex_steppers",
        /// Active sessions (pending/running, not suspended).
        SessionsLive => "optex_sessions_live",
        /// Suspended sessions.
        SessionsPaused => "optex_sessions_paused",
        /// Quarantined sessions still in the retention window.
        SessionsQuarantined => "optex_sessions_quarantined",
        /// Open client connections.
        ConnsActive => "optex_conns_active",
        /// Aggregate eval-time load: the sum over runnable sessions of
        /// their per-iteration eval-time EMA, in microseconds. The
        /// router's least-loaded placement signal (ISSUE 10) — read via
        /// the `stats` verb, it estimates how much sequential eval work
        /// this worker has queued.
        EvalLoad => "optex_eval_load_us",
    }
}

metric_enum! {
    /// Log2 histograms (exposition type `histogram`).
    Hist {
        /// Whole-quantum latency, microseconds (dispatch → reattach).
        QuantumLatencyUs => "optex_quantum_latency_us",
        /// Runnable-to-dispatch queue wait, microseconds.
        QueueWaitUs => "optex_queue_wait_us",
        /// Width the arbiter actually granted per quantum.
        GrantWidth => "optex_grant_width",
        /// Width the session wanted before budget pressure.
        DesiredWidth => "optex_desired_width",
        /// Gradient-prediction residual ‖μ̂−g‖/‖g‖ per mille — the
        /// adaptive-width precursor signal (ROADMAP).
        GradResidualPermille => "optex_grad_residual_permille",
    }
}

/// The bucket index for a histogram observation.
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `b` (the exposition `le` label);
/// the last bucket is unbounded.
pub fn bucket_le(b: usize) -> Option<u64> {
    if b + 1 >= BUCKETS {
        None
    } else {
        Some((1u64 << b) - 1)
    }
}

#[cfg(feature = "obs")]
struct HistShard {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

#[cfg(feature = "obs")]
impl HistShard {
    fn new() -> HistShard {
        HistShard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

#[cfg(feature = "obs")]
struct Shard {
    counters: [AtomicU64; Counter::COUNT],
    hists: Vec<HistShard>,
}

#[cfg(feature = "obs")]
impl Shard {
    fn new() -> Shard {
        Shard {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: (0..Hist::COUNT).map(|_| HistShard::new()).collect(),
        }
    }
}

#[cfg(feature = "obs")]
struct Inner {
    shards: Vec<Shard>,
    gauges: [AtomicU64; Gauge::COUNT],
}

#[cfg(feature = "obs")]
impl Inner {
    fn new() -> Inner {
        Inner {
            shards: (0..SHARDS).map(|_| Shard::new()).collect(),
            gauges: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

#[cfg(feature = "obs")]
fn shard_index() -> usize {
    // Stable per-thread shard assignment: dense indices from a global
    // counter, folded into the shard count. (`ThreadId::as_u64` is
    // unstable; this is the portable equivalent.)
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    IDX.with(|i| *i)
}

/// Cloneable metrics handle. Cheap to clone (one `Arc`), cheap to call
/// when disabled (one branch), free when the `obs` feature is off.
#[derive(Clone, Default)]
pub struct Registry {
    #[cfg(feature = "obs")]
    inner: Option<Arc<Inner>>,
}

impl Registry {
    /// An enabled registry (with the `obs` feature off this degrades to
    /// a disabled handle — there is nothing to record into).
    pub fn new() -> Registry {
        #[cfg(feature = "obs")]
        {
            Registry { inner: Some(Arc::new(Inner::new())) }
        }
        #[cfg(not(feature = "obs"))]
        {
            Registry {}
        }
    }

    /// A no-op handle: every record call is one `Option` branch.
    pub fn disabled() -> Registry {
        Registry::default()
    }

    pub fn enabled(&self) -> bool {
        #[cfg(feature = "obs")]
        {
            self.inner.is_some()
        }
        #[cfg(not(feature = "obs"))]
        {
            false
        }
    }

    /// Increment a counter by 1.
    #[inline]
    pub fn incr(&self, c: Counter) {
        self.add(c, 1);
    }

    /// Increment a counter by `v`.
    #[inline]
    pub fn add(&self, c: Counter, v: u64) {
        #[cfg(feature = "obs")]
        if let Some(inner) = &self.inner {
            inner.shards[shard_index()].counters[c as usize]
                .fetch_add(v, Ordering::Relaxed);
        }
        #[cfg(not(feature = "obs"))]
        let _ = (c, v);
    }

    /// Set a gauge (last write wins).
    #[inline]
    pub fn gauge_set(&self, g: Gauge, v: u64) {
        #[cfg(feature = "obs")]
        if let Some(inner) = &self.inner {
            inner.gauges[g as usize].store(v, Ordering::Relaxed);
        }
        #[cfg(not(feature = "obs"))]
        let _ = (g, v);
    }

    /// Record one histogram observation.
    #[inline]
    pub fn observe(&self, h: Hist, v: u64) {
        #[cfg(feature = "obs")]
        if let Some(inner) = &self.inner {
            let shard = &inner.shards[shard_index()].hists[h as usize];
            shard.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
            shard.sum.fetch_add(v, Ordering::Relaxed);
            shard.count.fetch_add(1, Ordering::Relaxed);
        }
        #[cfg(not(feature = "obs"))]
        let _ = (h, v);
    }

    /// Merged value of one counter (tests, the `stats` verb).
    pub fn counter(&self, c: Counter) -> u64 {
        #[cfg(feature = "obs")]
        if let Some(inner) = &self.inner {
            return inner
                .shards
                .iter()
                .map(|s| s.counters[c as usize].load(Ordering::Relaxed))
                .sum();
        }
        let _ = c;
        0
    }

    /// Current value of one gauge.
    pub fn gauge(&self, g: Gauge) -> u64 {
        #[cfg(feature = "obs")]
        if let Some(inner) = &self.inner {
            return inner.gauges[g as usize].load(Ordering::Relaxed);
        }
        let _ = g;
        0
    }

    /// Merge every shard into a point-in-time snapshot. Empty (all
    /// zeros) on a disabled handle, so exposition of a disabled
    /// registry is still well-formed.
    pub fn snapshot(&self) -> Snapshot {
        let counters = Counter::ALL.iter().map(|&c| (c.name(), self.counter(c))).collect();
        let gauges = Gauge::ALL.iter().map(|&g| (g.name(), self.gauge(g))).collect();
        let hists = Hist::ALL.iter().map(|&h| self.hist_snapshot(h)).collect();
        Snapshot { counters, gauges, hists }
    }

    fn hist_snapshot(&self, h: Hist) -> HistSnapshot {
        let mut snap = HistSnapshot {
            name: h.name(),
            buckets: [0; BUCKETS],
            count: 0,
            sum: 0,
        };
        #[cfg(feature = "obs")]
        if let Some(inner) = &self.inner {
            for shard in &inner.shards {
                let hs = &shard.hists[h as usize];
                for (acc, b) in snap.buckets.iter_mut().zip(&hs.buckets) {
                    *acc += b.load(Ordering::Relaxed);
                }
                snap.count += hs.count.load(Ordering::Relaxed);
                snap.sum += hs.sum.load(Ordering::Relaxed);
            }
        }
        let _ = h;
        snap
    }
}

/// A merged point-in-time view of the registry.
pub struct Snapshot {
    /// `(metric name, merged value)` in declaration order.
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<HistSnapshot>,
}

/// One merged histogram.
pub struct HistSnapshot {
    pub name: &'static str,
    /// Per-bucket observation counts (log2 buckets; see [`bucket_of`]).
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_edges() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // bucket b >= 1 covers [2^(b-1), 2^b): its le label is 2^b - 1
        assert_eq!(bucket_le(0), Some(0));
        assert_eq!(bucket_le(1), Some(1));
        assert_eq!(bucket_le(2), Some(3));
        assert_eq!(bucket_le(3), Some(7));
        assert_eq!(bucket_le(BUCKETS - 1), None, "last bucket is +Inf");
        for v in [1u64, 2, 3, 4, 5, 127, 128, 1 << 20, (1 << 20) + 1] {
            let b = bucket_of(v);
            if let Some(le) = bucket_le(b) {
                assert!(v <= le, "v={v} above its bucket's le={le}");
            }
            if b > 0 {
                let prev_le = bucket_le(b - 1).unwrap();
                assert!(v > prev_le, "v={v} belongs in an earlier bucket");
            }
        }
    }

    #[cfg(feature = "obs")]
    #[test]
    fn counters_merge_across_threads() {
        let reg = Registry::new();
        assert!(reg.enabled());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let reg = reg.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        reg.incr(Counter::Iterations);
                    }
                    reg.add(Counter::Retries, 3);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(reg.counter(Counter::Iterations), 8000);
        assert_eq!(reg.counter(Counter::Retries), 24);
        assert_eq!(reg.counter(Counter::Nonfinite), 0);
        let snap = reg.snapshot();
        let (name, v) = snap.counters[Counter::Iterations as usize];
        assert_eq!(name, "optex_iterations_total");
        assert_eq!(v, 8000);
    }

    #[cfg(feature = "obs")]
    #[test]
    fn histograms_merge_and_preserve_sum_count() {
        let reg = Registry::new();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                let reg = reg.clone();
                std::thread::spawn(move || {
                    for v in 0..100u64 {
                        reg.observe(Hist::GrantWidth, v + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = reg.snapshot();
        let h = &snap.hists[Hist::GrantWidth as usize];
        assert_eq!(h.name, "optex_grant_width");
        assert_eq!(h.count, 400);
        assert_eq!(h.buckets.iter().sum::<u64>(), 400);
        let want_sum: u64 = (0..4).map(|i| (0..100).map(|v| v + i).sum::<u64>()).sum();
        assert_eq!(h.sum, want_sum);
    }

    #[cfg(feature = "obs")]
    #[test]
    fn gauges_are_last_write_wins() {
        let reg = Registry::new();
        reg.gauge_set(Gauge::ArbiterInUse, 3);
        reg.gauge_set(Gauge::ArbiterInUse, 7);
        assert_eq!(reg.gauge(Gauge::ArbiterInUse), 7);
        assert_eq!(reg.gauge(Gauge::ArbiterPhysical), 0);
    }

    #[test]
    fn disabled_registry_is_inert_and_snapshotable() {
        let reg = Registry::disabled();
        assert!(!reg.enabled());
        reg.incr(Counter::Iterations);
        reg.observe(Hist::QuantumLatencyUs, 123);
        reg.gauge_set(Gauge::Steppers, 4);
        assert_eq!(reg.counter(Counter::Iterations), 0);
        assert_eq!(reg.gauge(Gauge::Steppers), 0);
        let snap = reg.snapshot();
        assert_eq!(snap.counters.len(), Counter::COUNT);
        assert!(snap.counters.iter().all(|&(_, v)| v == 0));
        assert!(snap.hists.iter().all(|h| h.count == 0));
    }

    #[test]
    fn metric_names_are_unique_and_prefixed() {
        let mut names: Vec<&str> = Counter::ALL
            .iter()
            .map(|c| c.name())
            .chain(Gauge::ALL.iter().map(|g| g.name()))
            .chain(Hist::ALL.iter().map(|h| h.name()))
            .collect();
        assert!(names.iter().all(|n| n.starts_with("optex_")));
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "metric names must be unique");
    }
}
