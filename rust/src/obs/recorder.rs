//! The per-session flight recorder: a bounded ring of sequence-numbered,
//! phase-tagged events, so a dead session carries its own post-mortem.
//!
//! Events come from two places. The driver accumulates them *during* a
//! quantum (retry, fault fired, nonfinite resync) — on whatever stepper
//! worker runs the quantum — and the serve thread drains them into the
//! session's ring at reattach, alongside its own lifecycle events
//! (begin_quantum, grant, quarantine, finish). Sequence numbers are
//! assigned by the ring at push, on the serve thread, so a session's
//! trace is a single totally-ordered log regardless of which thread ran
//! the work.
//!
//! Renders are deterministic: `#<seq> i<iter> <phase> <detail>` — no
//! wall-clock, ever. Trace output can therefore be byte-asserted in
//! tests and can never smuggle nondeterminism toward scenario goldens
//! (which ignore obs output entirely anyway).

use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;

/// Default ring capacity ([`FlightRecorder::with_capacity`] overrides).
pub const DEFAULT_RING: usize = 128;

/// What kind of event happened (the `phase` tag in trace renders).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TracePhase {
    /// Session admitted by the scheduler.
    Submit,
    /// A quantum was dispatched (serial step or stepper worker).
    BeginQuantum,
    /// The arbiter granted a width for the quantum.
    Grant,
    /// An eval fan-out attempt failed and was retried.
    Retry,
    /// An injected fault fired.
    Fault,
    /// Non-finite eval points were absorbed (`optex.on_nonfinite`).
    Nonfinite,
    /// A nonfinite resync evicted poisoned history (full GP refit).
    Resync,
    /// A panicking quantum was caught and the session quarantined.
    Quarantine,
    /// Checkpoint-backed suspend.
    Pause,
    /// Resume from suspend.
    Resume,
    /// Terminal transition (Done/Failed), with the stop reason.
    Finish,
}

impl TracePhase {
    pub fn name(self) -> &'static str {
        match self {
            TracePhase::Submit => "submit",
            TracePhase::BeginQuantum => "begin_quantum",
            TracePhase::Grant => "grant",
            TracePhase::Retry => "retry",
            TracePhase::Fault => "fault",
            TracePhase::Nonfinite => "nonfinite",
            TracePhase::Resync => "resync",
            TracePhase::Quarantine => "quarantine",
            TracePhase::Pause => "pause",
            TracePhase::Resume => "resume",
            TracePhase::Finish => "finish",
        }
    }
}

/// One recorded event. `iter` is the sequential iteration it belongs to
/// (0 for lifecycle events before the first iteration); `detail` is a
/// deterministic free-text tail (fault site, error text, stop reason).
#[derive(Clone, Debug)]
pub struct ObsEvent {
    pub phase: TracePhase,
    pub iter: u64,
    pub detail: String,
}

impl ObsEvent {
    pub fn new(phase: TracePhase, iter: u64, detail: impl Into<String>) -> ObsEvent {
        ObsEvent { phase, iter, detail: detail.into() }
    }
}

/// Bounded event ring with monotone sequence numbers. Old events fall
/// off the front; `next_seq` keeps counting, so a render always shows
/// how much history was dropped.
#[derive(Debug)]
pub struct FlightRecorder {
    cap: usize,
    next_seq: u64,
    ring: VecDeque<(u64, ObsEvent)>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new()
    }
}

impl FlightRecorder {
    pub fn new() -> FlightRecorder {
        FlightRecorder::with_capacity(DEFAULT_RING)
    }

    pub fn with_capacity(cap: usize) -> FlightRecorder {
        assert!(cap >= 1, "flight recorder needs room for one event");
        FlightRecorder { cap, next_seq: 0, ring: VecDeque::with_capacity(cap) }
    }

    /// Append one event, assigning it the next sequence number.
    pub fn push(&mut self, event: ObsEvent) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.ring.push_back((self.next_seq, event));
        self.next_seq += 1;
    }

    /// Events recorded over the ring's lifetime (≥ `len`).
    pub fn total_recorded(&self) -> u64 {
        self.next_seq
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Render the ring as deterministic trace lines, oldest first:
    /// `#<seq> i<iter> <phase>[ <detail>]`.
    pub fn render(&self) -> Vec<String> {
        self.ring
            .iter()
            .map(|(seq, e)| {
                if e.detail.is_empty() {
                    format!("#{seq} i{} {}", e.iter, e.phase.name())
                } else {
                    format!("#{seq} i{} {} {}", e.iter, e.phase.name(), e.detail)
                }
            })
            .collect()
    }

    /// Write the rendered ring to an on-disk artifact (the session
    /// post-mortem dumped at failure/quarantine). Best-effort contract
    /// is the caller's: a full disk must not take the serve loop down.
    pub fn dump(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        for line in self.render() {
            writeln!(f, "{line}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(phase: TracePhase, iter: u64, detail: &str) -> ObsEvent {
        ObsEvent::new(phase, iter, detail)
    }

    #[test]
    fn ring_wraps_and_seq_keeps_counting() {
        let mut r = FlightRecorder::with_capacity(3);
        for i in 0..5u64 {
            r.push(ev(TracePhase::BeginQuantum, i + 1, ""));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.total_recorded(), 5);
        let lines = r.render();
        // events 0 and 1 fell off; 2..=4 survive with original seqs
        assert_eq!(
            lines,
            vec![
                "#2 i3 begin_quantum",
                "#3 i4 begin_quantum",
                "#4 i5 begin_quantum",
            ]
        );
    }

    #[test]
    fn render_is_deterministic_and_wall_clock_free() {
        let build = || {
            let mut r = FlightRecorder::new();
            r.push(ev(TracePhase::Submit, 0, ""));
            r.push(ev(TracePhase::Grant, 1, "width=4 desired=8"));
            r.push(ev(TracePhase::Retry, 2, "injected fault: eval_err"));
            r.push(ev(TracePhase::Quarantine, 2, "panic in Driver::iteration"));
            r.push(ev(TracePhase::Finish, 2, "quarantined"));
            r.render().join("\n")
        };
        let a = build();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = build();
        assert_eq!(a, b, "trace renders must not depend on wall-clock");
        assert_eq!(
            a,
            "#0 i0 submit\n\
             #1 i1 grant width=4 desired=8\n\
             #2 i2 retry injected fault: eval_err\n\
             #3 i2 quarantine panic in Driver::iteration\n\
             #4 i2 finish quarantined"
        );
    }

    #[test]
    fn dump_writes_the_rendered_lines() {
        let dir = std::env::temp_dir().join("optex_obs_recorder_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace_1.txt");
        let mut r = FlightRecorder::new();
        r.push(ev(TracePhase::Fault, 3, "nan_row p1"));
        r.dump(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "#0 i3 fault nan_row p1\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
