//! Acceleration-rate study (extension): directly measure the effective
//! acceleration of SGD-based OptEx as a function of N and compare with
//! Cor. 2's Θ(√N).
//!
//! Protocol: run Vanilla to T_ref iterations on rosenbrock, record its
//! final optimality gap; for each N, find the sequential iteration at
//! which OptEx first reaches that gap; acceleration(N) = T_ref / T_N.
//! The paper's claim is acceleration(N) ≈ c·√N for N below N_max.

use anyhow::Result;

use crate::config::{Method, RunConfig};
use crate::coordinator::optex;
use crate::figures::common::{mean_metric, sweep_seeds, write_curves, Curve, FigOpts};
use crate::gp::Kernel;
use crate::opt::OptSpec;

fn cfg_for(opts: &FigOpts, method: Method, n: usize, steps: usize, d: usize) -> RunConfig {
    let mut c = RunConfig::default();
    c.workload = "rosenbrock".into();
    c.method = method;
    c.steps = steps;
    c.synth_dim = d;
    c.optimizer = OptSpec::Sgd { lr: 2e-4 * d as f64 }; // stable for rosenbrock
    c.optex.parallelism = n;
    c.optex.t0 = 20;
    c.optex.kernel = Kernel::Matern52;
    c.artifacts_dir = opts.artifacts_dir.clone();
    c
}

pub fn run(opts: &FigOpts) -> Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 100 } else { 400 });
    let d = if opts.quick { 500 } else { 4_000 };
    let out = opts.out_dir.join("fig_ext");

    // Vanilla reference gap at T_ref.
    let van = sweep_seeds(
        opts.seeds,
        &|seed| {
            let mut c = cfg_for(opts, Method::Vanilla, 1, steps, d);
            c.seed = seed;
            c
        },
        &optex::run,
    )?;
    let van_best = mean_metric(&van, &|r| r.best_loss_series());
    let target_gap = *van_best.last().unwrap();

    let ns: &[usize] = if opts.quick { &[2, 4, 8] } else { &[2, 3, 4, 5, 8, 12] };
    let mut xs = Vec::new();
    let mut measured = Vec::new();
    let mut predicted = Vec::new();
    println!("\n== Ext — acceleration rate vs N (Cor. 2: Θ(√N)) ==");
    println!("  vanilla gap at T={steps}: {target_gap:.3e}");
    for &n in ns {
        let recs = sweep_seeds(
            opts.seeds,
            &|seed| {
                let mut c = cfg_for(opts, Method::Optex, n, steps, d);
                c.seed = seed;
                c
            },
            &optex::run,
        )?;
        let best = mean_metric(&recs, &|r| r.best_loss_series());
        let reach = best.iter().position(|&b| b <= target_gap).map(|i| i + 1);
        let acc = reach.map(|t| steps as f64 / t as f64).unwrap_or(f64::NAN);
        println!(
            "  N={n:<3} reach@{:<6} acceleration={acc:.2}x  sqrt(N)={:.2}",
            reach.map(|t| t.to_string()).unwrap_or_else(|| "never".into()),
            (n as f64).sqrt()
        );
        xs.push(n as f64);
        measured.push(acc);
        predicted.push((n as f64).sqrt());
    }
    let curves = vec![
        Curve { label: "measured".into(), x: xs.clone(), y: measured },
        Curve { label: "sqrt_n".into(), x: xs, y: predicted },
    ];
    write_curves(&out.join("accel_vs_n.csv"), "N", "acceleration", &curves)?;
    Ok(())
}
