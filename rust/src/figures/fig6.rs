//! Figure 6 — ablations on Rosenbrock (paper Appx B.3):
//!   (a) parallel vs sequential intermediate-gradient evaluation,
//!   (b) θ_t selection principle: last / func / grad,
//!   (c) local-history length T₀ ∈ {1, 5, 10, 20, 50},
//!   (d) parallelism N ∈ {1, 2, 5, 10, 20}.
//!
//! Same optimizer protocol as Fig. 2; paper dimension 10⁵ (default 10⁴).

use anyhow::Result;

use crate::config::{Method, RunConfig};
use crate::coordinator::optex;
use crate::coordinator::Selection;
use crate::figures::common::{
    dump_records, mean_metric, print_panel, sweep_seeds, write_curves, Curve, FigOpts,
};
use crate::gp::Kernel;
use crate::opt::OptSpec;

fn base_cfg(opts: &FigOpts, steps: usize, d: usize) -> RunConfig {
    let mut c = RunConfig::default();
    c.workload = "rosenbrock".into();
    c.method = Method::Optex;
    c.steps = steps;
    c.synth_dim = d;
    c.noise_std = 0.0;
    c.optimizer = OptSpec::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
    c.optex.parallelism = 5;
    c.optex.t0 = 20;
    c.optex.kernel = Kernel::Matern52;
    c.artifacts_dir = opts.artifacts_dir.clone();
    c
}

fn panel(
    opts: &FigOpts,
    tag: &str,
    variants: Vec<(String, RunConfig)>,
) -> Result<()> {
    let out = opts.out_dir.join("fig6");
    let mut curves = Vec::new();
    for (label, cfg) in variants {
        let records = sweep_seeds(opts.seeds, &|seed| {
            let mut c = cfg.clone();
            c.seed = seed;
            c
        }, &optex::run)?;
        dump_records(&out, &format!("{tag}_{label}"), &records)?;
        let y = mean_metric(&records, &|r| r.best_loss_series());
        let x = (1..=y.len()).map(|i| i as f64).collect();
        curves.push(Curve { label, x, y });
    }
    write_curves(
        &out.join(format!("fig6{tag}.csv")),
        "seq_iter",
        "optimality_gap",
        &curves,
    )?;
    print_panel(&format!("Fig 6{tag} — rosenbrock ablation"), &curves, true);
    Ok(())
}

pub fn run(opts: &FigOpts, which: Option<char>) -> Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 40 } else { 150 });
    let d = if opts.quick { 1000 } else { 10_000 };

    let all = which.is_none();
    if all || which == Some('a') {
        let mut parallel = base_cfg(opts, steps, d);
        parallel.optex.eval_intermediate = true;
        let mut sequential = base_cfg(opts, steps, d);
        sequential.optex.eval_intermediate = false;
        panel(
            opts,
            "a",
            vec![("parallel".into(), parallel), ("sequential".into(), sequential)],
        )?;
    }
    if all || which == Some('b') {
        let variants = [Selection::Last, Selection::Func, Selection::Grad]
            .into_iter()
            .map(|s| {
                let mut c = base_cfg(opts, steps, d);
                c.optex.selection = s;
                (s.name().to_string(), c)
            })
            .collect();
        panel(opts, "b", variants)?;
    }
    if all || which == Some('c') {
        let t0s: &[usize] = if opts.quick { &[1, 10, 50] } else { &[1, 5, 10, 20, 50] };
        let variants = t0s
            .iter()
            .map(|&t0| {
                let mut c = base_cfg(opts, steps, d);
                c.optex.t0 = t0;
                (format!("T0={t0}"), c)
            })
            .collect();
        panel(opts, "c", variants)?;
    }
    if all || which == Some('d') {
        let ns: &[usize] = if opts.quick { &[1, 5, 20] } else { &[1, 2, 5, 10, 20] };
        let variants = ns
            .iter()
            .map(|&n| {
                let mut c = base_cfg(opts, steps, d);
                c.optex.parallelism = n;
                if n == 1 {
                    c.method = Method::Vanilla;
                }
                (format!("N={n}"), c)
            })
            .collect();
        panel(opts, "d", variants)?;
    }
    Ok(())
}
