//! Shared plumbing for the figure generators: multi-seed sweeps,
//! series aggregation, CSV emission and console tables.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::{Method, RunConfig};
use crate::coordinator::metrics::RunRecord;
use crate::util::csv::CsvWriter;
use crate::util::stats;

/// Options shared by every figure runner.
#[derive(Clone, Debug)]
pub struct FigOpts {
    pub out_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    /// Independent seeds per curve (paper: 5 synthetic / 3 RL & text).
    pub seeds: usize,
    /// Sequential iterations T (or episodes) per run; None = per-figure
    /// default.
    pub steps: Option<usize>,
    /// Smaller grids for smoke runs.
    pub quick: bool,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            out_dir: PathBuf::from("results"),
            artifacts_dir: PathBuf::from("artifacts"),
            seeds: 3,
            steps: None,
            quick: false,
        }
    }
}

/// The three headline methods of every paper panel.
pub const PANEL_METHODS: [Method; 3] = [Method::Vanilla, Method::Target, Method::Optex];

/// Run `make_cfg(seed)` for `seeds` seeds through the given runner and
/// return all records.
pub fn sweep_seeds(
    seeds: usize,
    make_cfg: &dyn Fn(u64) -> RunConfig,
    runner: &dyn Fn(&RunConfig) -> Result<RunRecord>,
) -> Result<Vec<RunRecord>> {
    let mut out = Vec::with_capacity(seeds);
    for s in 0..seeds {
        let cfg = make_cfg(s as u64);
        out.push(runner(&cfg)?);
    }
    Ok(out)
}

/// Element-wise mean of a metric across seed records.
pub fn mean_metric(records: &[RunRecord], metric: &dyn Fn(&RunRecord) -> Vec<f64>) -> Vec<f64> {
    let series: Vec<Vec<f64>> = records.iter().map(metric).collect();
    stats::mean_series(&series)
}

/// A labelled curve for a figure panel.
pub struct Curve {
    pub label: String,
    /// x values (iterations / episodes / seconds).
    pub x: Vec<f64>,
    /// y values (mean over seeds).
    pub y: Vec<f64>,
}

/// Write curves as a long-format CSV: label,x,y.
pub fn write_curves(path: &Path, xname: &str, yname: &str, curves: &[Curve]) -> Result<()> {
    let mut w = CsvWriter::create(path, &["series", xname, yname])?;
    for c in curves {
        for (&x, &y) in c.x.iter().zip(&c.y) {
            w.tagged_row(&c.label, &[x, y])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Console summary: final y per curve plus speedup-vs-first-curve at the
/// first curve's final y level (the paper's "iterations to reach the same
/// optimality gap" comparison).
pub fn print_panel(title: &str, curves: &[Curve], lower_is_better: bool) {
    println!("\n== {title} ==");
    let reference = curves.first();
    for c in curves {
        let last = *c.y.last().unwrap_or(&f64::NAN);
        let mut line = format!("  {:12} final={last:.4e}", c.label);
        if let Some(r) = reference {
            if c.label != r.label {
                if let Some(sp) = speedup_vs(r, c, lower_is_better) {
                    line.push_str(&format!("  speedup_vs_{}={sp:.2}x", r.label));
                }
            }
        }
        println!("{line}");
    }
}

/// x-ratio at which `c` first reaches the final level of `reference`.
pub fn speedup_vs(reference: &Curve, c: &Curve, lower_is_better: bool) -> Option<f64> {
    let target = *reference.y.last()?;
    let reached = c
        .x
        .iter()
        .zip(&c.y)
        .find(|(_, &y)| if lower_is_better { y <= target } else { y >= target })
        .map(|(&x, _)| x)?;
    let ref_x = *reference.x.last()?;
    if reached > 0.0 {
        Some(ref_x / reached)
    } else {
        None
    }
}

/// Write every per-seed record for provenance.
pub fn dump_records(dir: &Path, tag: &str, records: &[RunRecord]) -> Result<()> {
    for (i, r) in records.iter().enumerate() {
        r.to_csv(&dir.join(format!("{tag}_seed{i}.csv")))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::IterRecord;

    fn rec(label: &str, ys: &[f64]) -> RunRecord {
        let mut r = RunRecord::new(label);
        for (i, &y) in ys.iter().enumerate() {
            r.push(IterRecord {
                iter: i + 1,
                grad_evals: 0,
                loss: y,
                grad_norm: 0.0,
                best_loss: y,
                wall_s: 0.0,
                parallel_s: 0.0,
                eval_s: 0.0,
                est_var: 0.0,
                aux: None,
            });
        }
        r
    }

    #[test]
    fn mean_metric_averages_across_seeds() {
        let rs = vec![rec("a", &[2.0, 4.0]), rec("a", &[4.0, 8.0])];
        let m = mean_metric(&rs, &|r| r.loss_series());
        assert_eq!(m, vec![3.0, 6.0]);
    }

    #[test]
    fn speedup_detects_crossing() {
        let vanilla = Curve { label: "vanilla".into(), x: (1..=10).map(|i| i as f64).collect(), y: (1..=10).map(|i| 1.0 / i as f64).collect() };
        let optex = Curve { label: "optex".into(), x: (1..=10).map(|i| i as f64).collect(), y: (1..=10).map(|i| 0.5 / i as f64).collect() };
        // optex reaches 0.1 at x=5; vanilla at x=10 -> 2x
        let sp = speedup_vs(&vanilla, &optex, true).unwrap();
        assert!((sp - 2.0).abs() < 1e-9, "{sp}");
        // a worse curve that never reaches the target
        let bad = Curve { label: "bad".into(), x: vec![1.0, 2.0], y: vec![1.0, 0.9] };
        assert!(speedup_vs(&vanilla, &bad, true).is_none());
    }

    #[test]
    fn write_curves_emits_long_format() {
        let dir = std::env::temp_dir().join("optex_fig_common");
        let path = dir.join("c.csv");
        let c = Curve { label: "optex".into(), x: vec![1.0, 2.0], y: vec![0.5, 0.25] };
        write_curves(&path, "iter", "loss", &[c]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("series,iter,loss"));
        assert!(text.contains("optex,1,"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
