//! Figures 4 / 7 / 8 / 9 / 10 — neural-network training panels:
//! train loss / train error / held-out test error against BOTH sequential
//! iterations and (modeled-parallel) wallclock, for Vanilla / Target /
//! OptEx.
//!
//! Paper protocol (Appx B.2.3): SGD, lr = 1e-3 (images, batch 512) or
//! lr = 0.01 (text, batch 256), N = 4, T₀ = 6 (images) / 10 (text),
//! Matérn kernel, dim-subset D̃. The default artifact profile scales
//! batch/width down (DESIGN.md §Substitutions); shapes are preserved.

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::Driver;
use crate::datasets::{ImageDataset, ImageKind, N_CLASSES};
use crate::figures::common::{
    print_panel, write_curves, Curve, FigOpts, PANEL_METHODS,
};
use crate::opt::OptSpec;
use crate::runtime::{Engine, Executable, In, Manifest};
use crate::util::stats;
use crate::util::Rng;
use crate::workloads::factory;

/// One NN-training figure.
pub struct TrainFigSpec {
    /// "4a", "7", ...
    pub id: &'static str,
    /// factory workload name.
    pub workload: &'static str,
    pub lr: f64,
    /// Evaluate held-out test error (image classifiers).
    pub eval_test: bool,
    pub default_steps: usize,
}

pub const FIG4A: TrainFigSpec =
    TrainFigSpec { id: "4a", workload: "cifar", lr: 1e-3, eval_test: true, default_steps: 150 };
pub const FIG4B: TrainFigSpec = TrainFigSpec {
    id: "4b",
    workload: "shakespeare",
    lr: 0.01,
    eval_test: false,
    default_steps: 120,
};
pub const FIG7: TrainFigSpec =
    TrainFigSpec { id: "7", workload: "mnist", lr: 1e-3, eval_test: true, default_steps: 150 };
pub const FIG8: TrainFigSpec =
    TrainFigSpec { id: "8", workload: "fmnist", lr: 1e-3, eval_test: true, default_steps: 150 };
pub const FIG9: TrainFigSpec =
    TrainFigSpec { id: "9", workload: "cifar", lr: 1e-3, eval_test: true, default_steps: 150 };
pub const FIG10: TrainFigSpec =
    TrainFigSpec { id: "10", workload: "hp", lr: 0.01, eval_test: false, default_steps: 120 };

/// Held-out evaluator: runs the classifier artifact on test batches and
/// averages the `acc` output (the grad output is discarded — the
/// artifacts are fused loss+grad graphs).
struct TestEval {
    exe: Executable,
    ds: ImageDataset,
    batch: usize,
    batches: usize,
    rng: Rng,
}

impl TestEval {
    fn new(opts: &FigOpts, workload: &str, seed: u64) -> Result<TestEval> {
        let manifest = Manifest::load(&opts.artifacts_dir)?;
        let (artifact, kind) = match workload {
            "mnist" => ("mlp_mnist", ImageKind::MnistLike),
            "fmnist" => ("mlp_mnist", ImageKind::FashionLike),
            "cifar" => ("mlp_cifar", ImageKind::CifarLike),
            other => anyhow::bail!("no test evaluator for {other}"),
        };
        let spec = manifest.get(artifact)?;
        let batch = spec.meta_usize("batch")?;
        let engine = Engine::cpu()?;
        let exe = engine.load(spec)?;
        // Held-out set: same generator family, DIFFERENT seed stream than
        // the training split (factory uses seed ^ 0xDA7A).
        let ds = ImageDataset::generate(kind, 1000, seed ^ 0x7E57);
        Ok(TestEval { exe, ds, batch, batches: 3, rng: Rng::new(seed ^ 0x7E58) })
    }

    fn test_error(&mut self, theta: &[f32]) -> Result<f64> {
        let mut accs = Vec::with_capacity(self.batches);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        for _ in 0..self.batches {
            self.ds.sample_batch(self.batch, &mut self.rng, &mut x, &mut y);
            let out = self.exe.run(&[In::F32(theta), In::F32(&x), In::F32(&y)])?;
            accs.push(out[2][0] as f64);
        }
        debug_assert_eq!(y.len(), self.batch * N_CLASSES);
        Ok(1.0 - stats::mean(&accs))
    }
}

pub fn run(opts: &FigOpts, spec: &TrainFigSpec) -> Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 20 } else { spec.default_steps });
    let eval_every = (steps / 20).max(1);
    let out = opts.out_dir.join(format!("fig{}", spec.id));
    std::fs::create_dir_all(&out)?;

    // curves[metric][method]
    let mut loss_iter: Vec<Curve> = Vec::new();
    let mut loss_time: Vec<Curve> = Vec::new();
    let mut trainerr_iter: Vec<Curve> = Vec::new();
    let mut testerr_iter: Vec<Curve> = Vec::new();
    let mut testerr_time: Vec<Curve> = Vec::new();

    for method in PANEL_METHODS {
        // NN figures run 1 seed by default at CI scale (paper: 5/3) —
        // bump with --seeds.
        let seeds = opts.seeds.min(if opts.quick { 1 } else { 2 });
        let mut all_loss: Vec<Vec<f64>> = Vec::new();
        let mut all_time: Vec<Vec<f64>> = Vec::new();
        let mut all_acc: Vec<Vec<f64>> = Vec::new();
        let mut all_test: Vec<Vec<f64>> = Vec::new();
        let mut test_x: Vec<f64> = Vec::new();
        for seed in 0..seeds {
            let mut cfg = RunConfig::default();
            cfg.workload = spec.workload.into();
            cfg.method = method;
            cfg.steps = steps;
            cfg.seed = seed as u64;
            cfg.optimizer = OptSpec::Sgd { lr: spec.lr };
            cfg.optex.parallelism = 4;
            // T0 / D̃ pinned by the gp artifact when backend=hlo; native
            // estimation uses the paper values.
            cfg.optex.t0 = if spec.workload == "shakespeare" || spec.workload == "hp" {
                10
            } else {
                6
            };
            cfg.optex.dsub = Some(4096);
            cfg.optex.sigma2 = 0.01;
            cfg.artifacts_dir = opts.artifacts_dir.clone();

            let workload = factory::build(&cfg)?;
            let mut driver = Driver::new(cfg.clone(), workload)?;
            let mut tester = if spec.eval_test {
                Some(TestEval::new(opts, spec.workload, seed as u64)?)
            } else {
                None
            };
            let mut test_series = Vec::new();
            let mut txs = Vec::new();
            for t in 1..=steps {
                driver.iteration(t)?;
                if let Some(te) = tester.as_mut() {
                    if t % eval_every == 0 || t == steps {
                        test_series.push(te.test_error(driver.theta())?);
                        txs.push(t as f64);
                    }
                }
            }
            let rec = driver.record().clone();
            rec.to_csv(&out.join(format!(
                "{}_{}_seed{seed}.csv",
                spec.workload,
                method.name()
            )))?;
            all_loss.push(rec.loss_series());
            all_time.push(rec.rows.iter().map(|r| r.parallel_s).collect());
            all_acc.push(rec.aux_series());
            if !test_series.is_empty() {
                all_test.push(test_series);
                test_x = txs;
            }
        }
        let label = method.name().to_string();
        let loss = stats::mean_series(&all_loss);
        let time = stats::mean_series(&all_time);
        let iters: Vec<f64> = (1..=loss.len()).map(|i| i as f64).collect();
        loss_time.push(Curve { label: label.clone(), x: time.clone(), y: loss.clone() });
        loss_iter.push(Curve { label: label.clone(), x: iters.clone(), y: loss });
        let acc = stats::mean_series(&all_acc);
        if acc.iter().any(|a| a.is_finite()) {
            let err: Vec<f64> = acc.iter().map(|a| 1.0 - a).collect();
            trainerr_iter.push(Curve { label: label.clone(), x: iters.clone(), y: err });
        }
        if !all_test.is_empty() {
            let te = stats::mean_series(&all_test);
            // map test checkpoints onto the time axis
            let t_at: Vec<f64> = test_x
                .iter()
                .map(|&ti| time.get(ti as usize - 1).copied().unwrap_or(0.0))
                .collect();
            testerr_time.push(Curve { label: label.clone(), x: t_at, y: te.clone() });
            testerr_iter.push(Curve { label, x: test_x.clone(), y: te });
        }
    }

    write_curves(&out.join("train_loss_vs_iter.csv"), "seq_iter", "train_loss", &loss_iter)?;
    write_curves(&out.join("train_loss_vs_time.csv"), "parallel_s", "train_loss", &loss_time)?;
    if !trainerr_iter.is_empty() {
        write_curves(&out.join("train_err_vs_iter.csv"), "seq_iter", "train_err", &trainerr_iter)?;
    }
    if !testerr_iter.is_empty() {
        write_curves(&out.join("test_err_vs_iter.csv"), "seq_iter", "test_err", &testerr_iter)?;
        write_curves(&out.join("test_err_vs_time.csv"), "parallel_s", "test_err", &testerr_time)?;
    }
    print_panel(
        &format!("Fig {} — {} train loss vs iterations", spec.id, spec.workload),
        &loss_iter,
        true,
    );
    if !testerr_iter.is_empty() {
        print_panel(
            &format!("Fig {} — {} test error vs iterations", spec.id, spec.workload),
            &testerr_iter,
            true,
        );
    }
    Ok(())
}
