//! Figure 3 — cumulative average reward vs episodes for DQN on the
//! classic-control tasks, Vanilla vs Target vs OptEx.
//!
//! Paper protocol (Appx B.2.2): Adam lr = 1e-3, γ = 0.95, batch 256,
//! N = 4, T₀ = 150, ε-greedy with 2^(−1/1500) decay, warm-up episodes,
//! 100–200 episodes, mean of 3 runs.

use anyhow::Result;

use crate::config::RunConfig;
use crate::figures::common::{
    dump_records, mean_metric, print_panel, sweep_seeds, write_curves, Curve, FigOpts,
    PANEL_METHODS,
};
use crate::gp::Kernel;
use crate::opt::OptSpec;
use crate::rl::dqn::{train, RlConfig};
use crate::rl::ALL_ENVS;

pub fn run(opts: &FigOpts, env_filter: Option<&str>) -> Result<()> {
    let episodes = opts.steps.unwrap_or(if opts.quick { 20 } else { 80 });
    let out = opts.out_dir.join("fig3");
    for env in ALL_ENVS {
        if let Some(f) = env_filter {
            if f != env {
                continue;
            }
        }
        let mut rl = RlConfig::paper(env);
        rl.episodes = episodes;
        rl.warmup_episodes = (episodes / 6).max(2);
        if opts.quick {
            rl.batch = 64;
        }
        let mut curves = Vec::new();
        for method in PANEL_METHODS {
            let rl_c = rl.clone();
            let runner = move |cfg: &RunConfig| train(cfg, &rl_c);
            let make_cfg = |seed: u64| -> RunConfig {
                let mut c = RunConfig::default();
                c.workload = env.into();
                c.method = method;
                c.seed = seed;
                c.optimizer =
                    OptSpec::Adam { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
                c.optex.parallelism = 4;
                c.optex.t0 = 150;
                c.optex.kernel = Kernel::Matern52;
                c.optex.sigma2 = 0.01; // stochastic TD gradients
                c.artifacts_dir = opts.artifacts_dir.clone();
                c
            };
            let records = sweep_seeds(opts.seeds, &make_cfg, &runner)?;
            dump_records(&out, &format!("{env}_{}", method.name()), &records)?;
            let y = mean_metric(&records, &|r| r.aux_series());
            let x = (1..=y.len()).map(|i| i as f64).collect();
            curves.push(Curve { label: method.name().into(), x, y });
        }
        write_curves(
            &out.join(format!("fig3_{env}.csv")),
            "episode",
            "cum_avg_reward",
            &curves,
        )?;
        // higher reward is better
        print_panel(&format!("Fig 3 — {env} (N=4, T0=150)"), &curves, false);
    }
    Ok(())
}
