//! Extension figures beyond the paper (DESIGN.md §4 extensions):
//!   * `kernels`  — estimation error vs T₀ per kernel family (the shape
//!     check of Cor. 1: RBF fastest decay, Matérn-ν slower as ν drops),
//!   * `estbound` — measured ‖∇F − μ_t‖ against the Thm-1 envelope
//!     √(α‖Σ²‖) along a real optimization trajectory,
//!   * `nativehlo` — native vs HLO estimator agreement and latency.

use anyhow::Result;

use crate::figures::common::{print_panel, write_curves, Curve, FigOpts};
use crate::gp::{estimator, GpConfig, Kernel};
use crate::runtime::{Engine, In, Manifest};
use crate::util::stats;
use crate::util::Rng;
use crate::workloads::synthetic::SynthFn;

/// Collect a gradient history along a Vanilla-Adam trajectory, then
/// measure leave-latest-out estimation error as a function of T₀.
fn trajectory_history(d: usize, n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let f = SynthFn::Rosenbrock;
    let mut rng = Rng::new(seed);
    let mut theta: Vec<f32> = (0..d).map(|_| 1.0 + 2.0 + 0.5 * rng.normal() as f32).collect();
    let mut opt = crate::opt::OptSpec::parse("adam", 0.1).unwrap().build(d);
    let mut thetas = Vec::with_capacity(n);
    let mut grads = Vec::with_capacity(n);
    let mut g = vec![0.0f32; d];
    for _ in 0..n {
        f.value_and_grad(&theta, &mut g);
        thetas.push(theta.clone());
        grads.push(g.clone());
        opt.step(&mut theta, &g);
    }
    (thetas, grads)
}

pub fn run_kernels(opts: &FigOpts) -> Result<()> {
    let d = if opts.quick { 200 } else { 2000 };
    let n = 64;
    let t0s: &[usize] = &[2, 4, 8, 16, 32, 48];
    let out = opts.out_dir.join("fig_ext");
    let mut curves = Vec::new();
    for kernel in Kernel::ALL {
        let mut ys = Vec::new();
        for &t0 in t0s {
            let mut errs = Vec::new();
            for seed in 0..opts.seeds {
                let (thetas, grads) = trajectory_history(d, n, seed as u64);
                // predict the latest gradient from the preceding t0
                let q = n - 1;
                let lo = q.saturating_sub(t0);
                let hist: Vec<&[f32]> =
                    thetas[lo..q].iter().map(|v| v.as_slice()).collect();
                let gh: Vec<&[f32]> = grads[lo..q].iter().map(|v| v.as_slice()).collect();
                let cfg = GpConfig { kernel, lengthscale: None, sigma2: 1e-4, ..GpConfig::default() };
                let mut mu = vec![0.0f32; d];
                estimator::estimate(&cfg, &thetas[q], &hist, &gh, &mut mu);
                let err: f64 = mu
                    .iter()
                    .zip(&grads[q])
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt()
                    / stats::norm2(&grads[q]).max(1e-12);
                errs.push(err);
            }
            ys.push(stats::mean(&errs));
        }
        curves.push(Curve {
            label: kernel.name().into(),
            x: t0s.iter().map(|&t| t as f64).collect(),
            y: ys,
        });
    }
    write_curves(&out.join("kernels_err_vs_t0.csv"), "t0", "rel_err", &curves)?;
    print_panel("Ext — relative estimation error vs T0 per kernel", &curves, true);
    Ok(())
}

pub fn run_estbound(opts: &FigOpts) -> Result<()> {
    let d = if opts.quick { 200 } else { 2000 };
    let n = 48;
    let out = opts.out_dir.join("fig_ext");
    let (thetas, grads) = trajectory_history(d, n, 0);
    let cfg = GpConfig { kernel: Kernel::Matern52, lengthscale: None, sigma2: 1e-4, ..GpConfig::default() };
    // alpha = d + (sqrt(d)+1) ln(1/delta), delta = 0.1 (Thm. 1)
    let alpha = d as f64 + ((d as f64).sqrt() + 1.0) * (1.0f64 / 0.1).ln();
    let mut xs = Vec::new();
    let mut measured = Vec::new();
    let mut bound = Vec::new();
    let mut violations = 0usize;
    for q in 4..n {
        let lo = q.saturating_sub(16);
        let hist: Vec<&[f32]> = thetas[lo..q].iter().map(|v| v.as_slice()).collect();
        let gh: Vec<&[f32]> = grads[lo..q].iter().map(|v| v.as_slice()).collect();
        let mut mu = vec![0.0f32; d];
        let est = estimator::estimate(&cfg, &thetas[q], &hist, &gh, &mut mu);
        let err: f64 = mu
            .iter()
            .zip(&grads[q])
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let b = (alpha * est.var).sqrt();
        xs.push(q as f64);
        measured.push(err);
        bound.push(b);
        if err > b {
            violations += 1;
        }
    }
    let curves = vec![
        Curve { label: "measured_err".into(), x: xs.clone(), y: measured },
        Curve { label: "thm1_bound".into(), x: xs, y: bound },
    ];
    write_curves(&out.join("estbound.csv"), "step", "error", &curves)?;
    print_panel("Ext — Thm-1 bound vs measured error", &curves, true);
    println!("  bound violations: {violations} (expected ~0 at delta=0.1)");
    Ok(())
}

/// Remark-1 study: OptEx's speedup comes from fewer sequential
/// iterations, sample averaging's from variance reduction — they win in
/// different regimes (deterministic vs high-noise) and compose.
pub fn run_remark1(opts: &FigOpts) -> Result<()> {
    use crate::config::{Method, RunConfig};
    use crate::coordinator::optex;
    use crate::figures::common::{mean_metric, sweep_seeds};
    use crate::opt::OptSpec;

    let steps = opts.steps.unwrap_or(if opts.quick { 40 } else { 120 });
    // Small d: the paper-modified sphere has ‖∇F‖ ≈ 1/√d, so the noise
    // level must be commensurate for the variance-reduction regime to
    // exist at all (σ ≈ ‖∇F‖ here).
    let d = 100;
    let out = opts.out_dir.join("fig_ext");
    for (regime, noise) in [("deterministic", 0.0), ("noisy", 0.1)] {
        let mut curves = Vec::new();
        for method in [Method::Vanilla, Method::DataParallel, Method::Optex] {
            let make_cfg = |seed: u64| -> RunConfig {
                let mut c = RunConfig::default();
                c.workload = "sphere".into();
                c.method = method;
                c.steps = steps;
                c.seed = seed;
                c.synth_dim = d;
                c.noise_std = noise;
                c.optimizer = OptSpec::Sgd { lr: 8.0 }; // ≈ 1/L for this F
                c.optex.parallelism = 8;
                c.optex.t0 = 16;
                c.optex.sigma2 = (noise * noise).max(1e-6);
                c
            };
            let records = sweep_seeds(opts.seeds, &make_cfg, &optex::run)?;
            let y = mean_metric(&records, &|r| r.best_loss_series());
            let x = (1..=y.len()).map(|i| i as f64).collect();
            curves.push(Curve { label: method.name().into(), x, y });
        }
        write_curves(
            &out.join(format!("remark1_{regime}.csv")),
            "seq_iter",
            "optimality_gap",
            &curves,
        )?;
        print_panel(
            &format!("Ext Remark-1 — sphere {regime} (σ={noise}, N=8)"),
            &curves,
            true,
        );
    }
    Ok(())
}

pub fn run_native_vs_hlo(opts: &FigOpts) -> Result<()> {
    let manifest = Manifest::load(&opts.artifacts_dir)?;
    let out = opts.out_dir.join("fig_ext");
    let mut report = Vec::new();
    for spec in manifest.by_family("gp_estimate") {
        let t0 = spec.meta_usize("t0")?;
        let dsub = spec.meta_usize("dsub")?;
        let d = spec.dim()?;
        if d > 5_000_000 {
            continue;
        }
        let kernel = Kernel::parse(spec.meta_str("kernel")?).unwrap();
        let engine = Engine::cpu()?;
        let exe = engine.load(spec)?;
        let mut rng = Rng::new(7);
        let theta_sub = rng.normal_vec(dsub);
        let hist: Vec<Vec<f32>> = (0..t0).map(|_| rng.normal_vec(dsub)).collect();
        let grads: Vec<Vec<f32>> = (0..t0).map(|_| rng.normal_vec(d)).collect();
        let hist_flat = hist.concat();
        let grads_flat = grads.concat();
        let (ls, s2) = (2.0f32, 0.05f32);

        let t_hlo = std::time::Instant::now();
        let outp = exe.run(&[
            In::F32(&theta_sub),
            In::F32(&hist_flat),
            In::F32(&grads_flat),
            In::F32(&[ls]),
            In::F32(&[s2]),
        ])?;
        let hlo_ms = t_hlo.elapsed().as_secs_f64() * 1e3;

        let cfg = GpConfig { kernel, lengthscale: Some(ls as f64), sigma2: s2 as f64, ..GpConfig::default() };
        let hrefs: Vec<&[f32]> = hist.iter().map(|v| v.as_slice()).collect();
        let grefs: Vec<&[f32]> = grads.iter().map(|v| v.as_slice()).collect();
        let mut mu = vec![0.0f32; d];
        let t_nat = std::time::Instant::now();
        estimator::estimate(&cfg, &theta_sub, &hrefs, &grefs, &mut mu);
        let nat_ms = t_nat.elapsed().as_secs_f64() * 1e3;

        let max_diff = outp[0]
            .iter()
            .zip(&mu)
            .map(|(&a, &b)| (a - b).abs() as f64)
            .fold(0.0f64, f64::max);
        println!(
            "  {:24} T0={t0:<4} dsub={dsub:<6} d={d:<8} max|Δμ|={max_diff:.2e} \
             native={nat_ms:.2}ms hlo={hlo_ms:.2}ms",
            spec.name
        );
        report.push((spec.name.clone(), max_diff, nat_ms, hlo_ms));
    }
    let mut w = crate::util::csv::CsvWriter::create(
        &out.join("native_vs_hlo.csv"),
        &["artifact", "max_abs_diff", "native_ms", "hlo_ms"],
    )?;
    for (name, diff, nat, hlo) in &report {
        w.tagged_row(name, &[*diff, *nat, *hlo])?;
    }
    w.flush()?;
    anyhow::ensure!(
        report.iter().all(|(_, diff, _, _)| *diff < 1e-2),
        "native/hlo estimator divergence"
    );
    Ok(())
}
