//! Figure 2 — optimality gap vs sequential iterations on the synthetic
//! functions (Ackley / Sphere / Rosenbrock), Vanilla vs Target vs OptEx.
//!
//! Paper protocol (Appx B.2.1): Adam lr = 0.1 (β₁ = .9, β₂ = .999),
//! N = 5, T₀ = 20, Matérn kernel, σ² = 0 (deterministic), mean of 5 runs.
//! Default profile uses d = 10⁴ (paper 10⁵ via `--set synth_dim=100000`)
//! and 3 seeds; shapes — who wins and by what factor — are d-independent
//! (Thm. 2's rate does not involve d).

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::optex;
use crate::figures::common::{
    dump_records, mean_metric, print_panel, sweep_seeds, write_curves, Curve, FigOpts,
    PANEL_METHODS,
};
use crate::gp::Kernel;
use crate::opt::OptSpec;
use crate::workloads::synthetic::SynthFn;

pub fn run(opts: &FigOpts) -> Result<()> {
    let steps = opts.steps.unwrap_or(if opts.quick { 40 } else { 200 });
    let d = if opts.quick { 1000 } else { 10_000 };
    let out = opts.out_dir.join("fig2");
    for f in SynthFn::ALL {
        let mut curves = Vec::new();
        for method in PANEL_METHODS {
            let make_cfg = |seed: u64| -> RunConfig {
                let mut c = RunConfig::default();
                c.workload = f.name().into();
                c.method = method;
                c.steps = steps;
                c.seed = seed;
                c.synth_dim = d;
                c.noise_std = 0.0; // deterministic, paper Sec. 6.1
                c.optimizer =
                    OptSpec::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
                c.optex.parallelism = 5;
                c.optex.t0 = 20;
                c.optex.kernel = Kernel::Matern52;
                c.optex.sigma2 = 0.0;
                c.artifacts_dir = opts.artifacts_dir.clone();
                c
            };
            let records = sweep_seeds(opts.seeds, &make_cfg, &optex::run)?;
            dump_records(&out, &format!("{}_{}", f.name(), method.name()), &records)?;
            let y = mean_metric(&records, &|r| r.best_loss_series());
            let x = (1..=y.len()).map(|i| i as f64).collect();
            curves.push(Curve { label: method.name().into(), x, y });
        }
        write_curves(
            &out.join(format!("fig2_{}.csv", f.name())),
            "seq_iter",
            "optimality_gap",
            &curves,
        )?;
        print_panel(&format!("Fig 2 — {} (d={d}, N=5)", f.name()), &curves, true);
    }
    Ok(())
}
