//! Figure-regeneration harness: one entrypoint per paper figure plus the
//! extension studies (see DESIGN.md §4 for the experiment index).
//!
//! `optex fig <id>` writes CSV series under `results/fig<id>/` and prints
//! a console summary with speedup factors. IDs: 2, 3, 4a, 4b, 6, 6a–6d,
//! 7, 8, 9, 10, kernels, estbound, nativehlo, all.

pub mod common;
pub mod fig2;
pub mod fig_accel;
pub mod fig3;
pub mod fig6;
pub mod fig_ext;
pub mod fig_train;

use anyhow::{bail, Result};
pub use common::FigOpts;

/// Dispatch a figure id.
pub fn run(id: &str, opts: &FigOpts) -> Result<()> {
    match id {
        "2" => fig2::run(opts),
        "3" => fig3::run(opts, None),
        "3-cartpole" => fig3::run(opts, Some("cartpole")),
        "3-mountaincar" => fig3::run(opts, Some("mountaincar")),
        "3-acrobot" => fig3::run(opts, Some("acrobot")),
        "4a" => fig_train::run(opts, &fig_train::FIG4A),
        "4b" => fig_train::run(opts, &fig_train::FIG4B),
        "6" => fig6::run(opts, None),
        "6a" => fig6::run(opts, Some('a')),
        "6b" => fig6::run(opts, Some('b')),
        "6c" => fig6::run(opts, Some('c')),
        "6d" => fig6::run(opts, Some('d')),
        "7" => fig_train::run(opts, &fig_train::FIG7),
        "8" => fig_train::run(opts, &fig_train::FIG8),
        "9" => fig_train::run(opts, &fig_train::FIG9),
        "10" => fig_train::run(opts, &fig_train::FIG10),
        "kernels" => fig_ext::run_kernels(opts),
        "estbound" => fig_ext::run_estbound(opts),
        "remark1" => fig_ext::run_remark1(opts),
        "accel" => fig_accel::run(opts),
        "nativehlo" => fig_ext::run_native_vs_hlo(opts),
        "all" => {
            for id in ["2", "6", "kernels", "estbound", "remark1", "3", "4a", "4b", "7", "8", "9", "10"] {
                println!("\n##### fig {id} #####");
                run(id, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown figure id {other:?} (try: 2 3 4a 4b 6 7 8 9 10 kernels estbound remark1 accel nativehlo all)"),
    }
}
