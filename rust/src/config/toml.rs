//! Minimal TOML-subset parser (no `toml`/`serde` crates offline).
//!
//! Supports what the run configs need:
//!   * `[table]` and `[dotted.table]` headers,
//!   * `key = value` with string / integer / float / bool / flat arrays,
//!   * `#` comments and blank lines.
//!
//! Not supported (rejected with a line-numbered error, never silently
//! misparsed): multi-line strings, inline tables, array-of-tables,
//! datetimes.
//!
//! Values land in a flat `BTreeMap<String, Value>` keyed by
//! `table.subkey` paths, which the typed layer (`schema.rs`) consumes.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML scalar or flat array.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Line-numbered parse error.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// Parse a document into a flat `path -> value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>, TomlError> {
    let mut map = BTreeMap::new();
    let mut prefix = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        let err = |m: &str| TomlError { line: lineno + 1, message: m.to_string() };
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            if line.starts_with("[[") {
                return Err(err("array-of-tables is not supported"));
            }
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated table header"))?
                .trim();
            if name.is_empty() || !name.split('.').all(is_bare_key) {
                return Err(err("invalid table name"));
            }
            prefix = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected `key = value`"))?;
        let key = line[..eq].trim();
        if !is_bare_key(key) {
            return Err(err(&format!("invalid key {key:?}")));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|m| err(&m))?;
        let path = if prefix.is_empty() {
            key.to_string()
        } else {
            format!("{prefix}.{key}")
        };
        if map.insert(path.clone(), val).is_some() {
            return Err(err(&format!("duplicate key {path:?}")));
        }
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn is_bare_key(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => return Err(format!("bad escape \\{other:?}")),
                }
            } else if c == '"' {
                return Err("stray quote inside string".into());
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner)? {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Arr(items));
    }
    // numbers: underscores allowed as separators
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        cleaned.parse::<f64>().map(Value::Float).map_err(|_| format!("bad float {s:?}"))
    } else {
        cleaned.parse::<i64>().map(Value::Int).map_err(|_| format!("bad value {s:?}"))
    }
}

/// Split an array body on top-level commas (strings may contain commas).
fn split_top_level(s: &str) -> Result<Vec<&str>, String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.checked_sub(1).ok_or("unbalanced ]")?,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_run_config() {
        let doc = r#"
            # OptEx run config
            workload = "rosenbrock"
            steps = 200
            seed = 7

            [optex]
            parallelism = 5
            t0 = 20
            kernel = "matern52"   # paper B.2.1
            sigma2 = 0.0
            lr = 1e-1

            [optimizer]
            name = "adam"
            betas = [0.9, 0.999]
            nesterov = false
        "#;
        let m = parse(doc).unwrap();
        assert_eq!(m["workload"].as_str(), Some("rosenbrock"));
        assert_eq!(m["steps"].as_usize(), Some(200));
        assert_eq!(m["optex.parallelism"].as_usize(), Some(5));
        assert_eq!(m["optex.kernel"].as_str(), Some("matern52"));
        assert_eq!(m["optex.sigma2"].as_f64(), Some(0.0));
        assert_eq!(m["optex.lr"].as_f64(), Some(0.1));
        assert_eq!(m["optimizer.nesterov"].as_bool(), Some(false));
        let betas = m["optimizer.betas"].as_arr().unwrap();
        assert_eq!(betas[1].as_f64(), Some(0.999));
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let m = parse(r#"s = "a#b\n\"c\"""#).unwrap();
        assert_eq!(m["s"].as_str(), Some("a#b\n\"c\""));
    }

    #[test]
    fn numbers_with_underscores() {
        let m = parse("d = 2_412_298\nx = 1_000.5").unwrap();
        assert_eq!(m["d"].as_i64(), Some(2412298));
        assert_eq!(m["x"].as_f64(), Some(1000.5));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "key",
            "= 3",
            "[unclosed",
            "[[arr]]",
            "k = ",
            "k = \"open",
            "k = [1, 2",
            "a.b = 1", // dotted keys only via table headers
            "k = 1\nk = 2",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn empty_and_comment_only() {
        assert!(parse("").unwrap().is_empty());
        assert!(parse("# just a comment\n\n").unwrap().is_empty());
    }

    #[test]
    fn nested_arrays() {
        let m = parse("a = [[1, 2], [3]]").unwrap();
        let outer = m["a"].as_arr().unwrap();
        assert_eq!(outer[0].as_arr().unwrap()[1].as_i64(), Some(2));
        assert_eq!(outer[1].as_arr().unwrap()[0].as_i64(), Some(3));
    }
}
