//! Typed run configuration (the framework's config system).
//!
//! Configs are TOML files (parsed by the from-scratch [`toml`] subset
//! parser) with CLI `--set key=value` overrides. Every knob of Algo. 1 and
//! of the baselines is reachable from here; `configs/*.toml` in the repo
//! root mirror the paper's Appx-B.2 experiment settings.

pub mod toml;

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::coordinator::selection::Selection;
use crate::gp::{GpFit, Kernel};
use crate::opt::{OptSpec, Schedule};
use crate::runtime::PoolMode;
use crate::serve::Policy;
use toml::Value;

/// Which iteration scheme drives the run (paper Fig. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Algo. 1 — proxy updates on estimated gradients, then N parallel
    /// ground-truth steps.
    Optex,
    /// Standard sequential FOO (Algo. 1 with N = 1).
    Vanilla,
    /// Ideal parallelization: ground-truth gradients for the chain
    /// (impractical upper baseline).
    Target,
    /// Sample-averaging baseline (Remark 1): N gradients at the SAME
    /// point, averaged.
    DataParallel,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "optex" => Some(Method::Optex),
            "vanilla" => Some(Method::Vanilla),
            "target" => Some(Method::Target),
            "dataparallel" | "data_parallel" => Some(Method::DataParallel),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Optex => "optex",
            Method::Vanilla => "vanilla",
            Method::Target => "target",
            Method::DataParallel => "dataparallel",
        }
    }
}

/// Gradient-estimation backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// rust/src/gp (request path stays rust-only either way).
    Native,
    /// AOT gp_estimate artifact through PJRT.
    Hlo,
}

/// OptEx-specific knobs (paper Sec. 4 + Appx B.2).
#[derive(Clone, Debug)]
pub struct OptexParams {
    /// Parallelism N.
    pub parallelism: usize,
    /// Local-history length T₀.
    pub t0: usize,
    pub kernel: Kernel,
    /// None -> median heuristic.
    pub lengthscale: Option<f64>,
    /// Observation noise σ².
    pub sigma2: f64,
    /// Kernel dim-subset size D̃ (None -> full d).
    pub dsub: Option<usize>,
    /// θ_t selection principle (Fig. 6b): last / func / grad.
    pub selection: Selection,
    /// Evaluate intermediate gradients (Fig. 6a ablation; true = paper
    /// Algo. 1 line 7).
    pub eval_intermediate: bool,
    pub backend: Backend,
    /// GP fit engine: `incremental` (rank-1 factor up/downdates across
    /// iterations, the default) or `full` (from-scratch reference refit).
    pub fit: GpFit,
    /// Periodic factor refresh for pinned-lengthscale incremental runs:
    /// every K syncs the Cholesky factor is refactorized from the cached
    /// distances, bounding rank-1 chain drift on very long runs. 0
    /// (default) = off; no effect under the median heuristic or the
    /// `full` engine.
    pub gp_refresh_every: usize,
    /// Native compute pool width for the eval_batch fan-out and the GP
    /// hot loops. 0 = auto-detect available parallelism (default);
    /// 1 = legacy serial path (kept for differential testing).
    /// Trajectories are bit-identical at any value.
    pub threads: usize,
    /// Native pool execution substrate: `scoped` (spawn per call,
    /// default) or `persistent` (process-global parked workers — the
    /// profile for long-lived `serve` processes). Never a numerics fork:
    /// trajectories are bit-identical across modes.
    pub pool: PoolMode,
}

impl Default for OptexParams {
    fn default() -> Self {
        OptexParams {
            parallelism: 4,
            t0: 10,
            kernel: Kernel::Matern52,
            lengthscale: None,
            sigma2: 0.0,
            dsub: None,
            selection: Selection::Last,
            eval_intermediate: true,
            backend: Backend::Native,
            fit: GpFit::Incremental,
            gp_refresh_every: 0,
            threads: 0,
            pool: PoolMode::Scoped,
        }
    }
}

/// `[serve]` table: the multi-session serving subsystem (ISSUE 4).
#[derive(Clone, Debug)]
pub struct ServeParams {
    /// Listen address for the JSONL wire protocol (`host:port`; port 0
    /// binds an ephemeral port, printed at startup).
    pub addr: String,
    /// Admission cap: sessions in Pending/Running/Paused at once.
    /// Submissions beyond it are rejected at the protocol level.
    pub max_sessions: usize,
    /// Iteration scheduling policy: `rr` (deterministic round-robin,
    /// default) or `fair` (weighted-fair on the per-session eval-seconds
    /// EMA). Either way trajectories are bit-identical to solo runs —
    /// the scheduler never reorders work *within* a session.
    pub policy: Policy,
    /// Directory for checkpoint-backed suspend files of paused sessions.
    pub ckpt_dir: PathBuf,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            addr: "127.0.0.1:7878".into(),
            max_sessions: 64,
            policy: Policy::RoundRobin,
            ckpt_dir: PathBuf::from("results/serve_ckpt"),
        }
    }
}

/// Complete run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Workload id: synthetic fn name, "mnist", "fmnist", "cifar",
    /// "tfm_char", or an RL env ("cartpole", ...).
    pub workload: String,
    pub method: Method,
    /// Sequential iterations T (episodes for RL).
    pub steps: usize,
    pub seed: u64,
    pub optimizer: OptSpec,
    /// Learning-rate schedule applied on top of the base lr.
    pub schedule: Schedule,
    pub optex: OptexParams,
    /// Multi-session serving knobs (`optex serve`).
    pub serve: ServeParams,
    /// Extra gaussian gradient noise std for synthetic workloads (σ of
    /// Assump. 1; 0 = deterministic, paper Sec. 6.1).
    pub noise_std: f64,
    /// Synthetic-function dimension override (d).
    pub synth_dim: usize,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    /// Record metrics every k-th sequential iteration.
    pub log_every: usize,
    /// Use HLO workload oracle instead of the native one where available.
    pub hlo_workload: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: "rosenbrock".into(),
            method: Method::Optex,
            steps: 100,
            seed: 0,
            optimizer: OptSpec::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            schedule: Schedule::Constant,
            optex: OptexParams::default(),
            serve: ServeParams::default(),
            noise_std: 0.0,
            synth_dim: 10_000,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            log_every: 1,
            hlo_workload: false,
        }
    }
}

/// Config error with the offending key.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn bad(key: &str, why: &str) -> ConfigError {
    ConfigError(format!("{key}: {why}"))
}

impl RunConfig {
    /// Parse a TOML document, starting from defaults.
    pub fn from_toml(text: &str) -> Result<RunConfig, ConfigError> {
        let map = toml::parse(text).map_err(|e| ConfigError(e.to_string()))?;
        let mut cfg = RunConfig::default();
        for (k, v) in &map {
            cfg.apply(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `--set key=value` CLI overrides after file parsing.
    pub fn apply_override(&mut self, kv: &str) -> Result<(), ConfigError> {
        let (k, raw) = kv
            .split_once('=')
            .ok_or_else(|| bad(kv, "override must be key=value"))?;
        // Reuse the TOML value grammar for the right-hand side; bare words
        // (e.g. `workload=mnist`) are treated as strings.
        let v = toml::parse(&format!("x = {raw}"))
            .map(|m| m["x"].clone())
            .unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.apply(k.trim(), &v)?;
        self.validate()
    }

    fn apply(&mut self, key: &str, v: &Value) -> Result<(), ConfigError> {
        let need_str = || v.as_str().ok_or_else(|| bad(key, "expected string"));
        let need_f64 = || v.as_f64().ok_or_else(|| bad(key, "expected number"));
        let need_usize = || v.as_usize().ok_or_else(|| bad(key, "expected non-negative integer"));
        let need_bool = || v.as_bool().ok_or_else(|| bad(key, "expected bool"));
        match key {
            "workload" => self.workload = need_str()?.to_string(),
            "method" => {
                self.method = Method::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown method"))?
            }
            "steps" => self.steps = need_usize()?,
            "seed" => self.seed = need_usize()? as u64,
            "noise_std" => self.noise_std = need_f64()?,
            "synth_dim" => self.synth_dim = need_usize()?,
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(need_str()?),
            "out_dir" => self.out_dir = PathBuf::from(need_str()?),
            "log_every" => self.log_every = need_usize()?.max(1),
            "hlo_workload" => self.hlo_workload = need_bool()?,
            "optimizer.name" => {
                let lr = self.optimizer.lr();
                self.optimizer = OptSpec::parse(need_str()?, lr)
                    .ok_or_else(|| bad(key, "unknown optimizer"))?;
            }
            "optimizer.schedule" => {
                self.schedule = Schedule::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown schedule (constant|warmup:K|step:K:G|cosine:H:F|theory:N:T)"))?;
            }
            "optimizer.lr" => {
                let lr = need_f64()?;
                self.optimizer = OptSpec::parse(self.optimizer.name(), lr)
                    .expect("known optimizer name");
            }
            "optex.parallelism" => self.optex.parallelism = need_usize()?,
            "optex.t0" => self.optex.t0 = need_usize()?,
            "optex.kernel" => {
                self.optex.kernel = Kernel::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown kernel"))?
            }
            "optex.lengthscale" => {
                let l = need_f64()?;
                self.optex.lengthscale = if l > 0.0 { Some(l) } else { None };
            }
            "optex.sigma2" => self.optex.sigma2 = need_f64()?,
            "optex.dsub" => {
                let d = need_usize()?;
                self.optex.dsub = if d > 0 { Some(d) } else { None };
            }
            "optex.selection" => {
                self.optex.selection = Selection::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown selection principle"))?
            }
            "optex.eval_intermediate" => self.optex.eval_intermediate = need_bool()?,
            "optex.backend" => {
                self.optex.backend = match need_str()? {
                    "native" => Backend::Native,
                    "hlo" => Backend::Hlo,
                    other => return Err(bad(key, &format!("unknown backend {other:?}"))),
                }
            }
            "optex.fit" => {
                self.optex.fit = GpFit::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown fit engine (full|incremental)"))?
            }
            "optex.gp_refresh_every" => self.optex.gp_refresh_every = need_usize()?,
            "optex.threads" => self.optex.threads = need_usize()?,
            "optex.pool" => {
                self.optex.pool = PoolMode::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown pool mode (scoped|persistent)"))?
            }
            "serve.addr" => self.serve.addr = need_str()?.to_string(),
            "serve.max_sessions" => self.serve.max_sessions = need_usize()?,
            "serve.policy" => {
                self.serve.policy = Policy::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown serve policy (rr|fair)"))?
            }
            "serve.ckpt_dir" => self.serve.ckpt_dir = PathBuf::from(need_str()?),
            _ => return Err(bad(key, "unknown config key")),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.optex.parallelism == 0 {
            return Err(bad("optex.parallelism", "must be >= 1"));
        }
        if self.optex.t0 == 0 {
            return Err(bad("optex.t0", "must be >= 1"));
        }
        if self.steps == 0 {
            return Err(bad("steps", "must be >= 1"));
        }
        if self.optex.sigma2 < 0.0 {
            return Err(bad("optex.sigma2", "must be >= 0"));
        }
        if self.noise_std < 0.0 {
            return Err(bad("noise_std", "must be >= 0"));
        }
        if self.synth_dim == 0 {
            return Err(bad("synth_dim", "must be >= 1"));
        }
        if self.serve.max_sessions == 0 {
            return Err(bad("serve.max_sessions", "must be >= 1"));
        }
        if self.serve.addr.is_empty() {
            return Err(bad("serve.addr", "must be host:port"));
        }
        Ok(())
    }

    /// Flatten back to key/value pairs (for run provenance records).
    pub fn describe(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        m.insert("workload".into(), self.workload.clone());
        m.insert("method".into(), self.method.name().into());
        m.insert("steps".into(), self.steps.to_string());
        m.insert("seed".into(), self.seed.to_string());
        m.insert("optimizer".into(), self.optimizer.name().into());
        m.insert("lr".into(), format!("{}", self.optimizer.lr()));
        m.insert("schedule".into(), format!("{:?}", self.schedule));
        m.insert("N".into(), self.optex.parallelism.to_string());
        m.insert("T0".into(), self.optex.t0.to_string());
        m.insert("kernel".into(), self.optex.kernel.name().into());
        m.insert("sigma2".into(), format!("{}", self.optex.sigma2));
        m.insert("selection".into(), self.optex.selection.name().into());
        m.insert("fit".into(), self.optex.fit.name().into());
        m.insert("gp_refresh_every".into(), self.optex.gp_refresh_every.to_string());
        m.insert("threads".into(), self.optex.threads.to_string());
        m.insert("pool".into(), self.optex.pool.name().into());
        m.insert("noise_std".into(), format!("{}", self.noise_std));
        m.insert("synth_dim".into(), self.synth_dim.to_string());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn full_document_roundtrip() {
        let doc = r#"
            workload = "sphere"
            method = "target"
            steps = 50
            seed = 3
            noise_std = 0.1
            synth_dim = 1000

            [optimizer]
            name = "sgd"
            lr = 0.01

            [optex]
            parallelism = 5
            t0 = 20
            kernel = "rbf"
            sigma2 = 0.05
            dsub = 256
            selection = "func"
            eval_intermediate = false
            backend = "native"
            fit = "full"
        "#;
        let cfg = RunConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.workload, "sphere");
        assert_eq!(cfg.method, Method::Target);
        assert_eq!(cfg.optimizer, OptSpec::Sgd { lr: 0.01 });
        assert_eq!(cfg.optex.parallelism, 5);
        assert_eq!(cfg.optex.kernel, Kernel::Rbf);
        assert_eq!(cfg.optex.dsub, Some(256));
        assert!(!cfg.optex.eval_intermediate);
        assert_eq!(cfg.optex.selection, Selection::Func);
        assert_eq!(cfg.optex.fit, GpFit::Full);
    }

    #[test]
    fn threads_knob_parses_with_zero_as_auto_default() {
        assert_eq!(RunConfig::default().optex.threads, 0);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.threads=8").unwrap();
        assert_eq!(cfg.optex.threads, 8);
        cfg.apply_override("optex.threads=1").unwrap();
        assert_eq!(cfg.optex.threads, 1);
        assert!(cfg.apply_override("optex.threads=-2").is_err());
        assert!(RunConfig::default().describe().contains_key("threads"));
    }

    #[test]
    fn pool_mode_knob_parses_with_scoped_default() {
        assert_eq!(RunConfig::default().optex.pool, PoolMode::Scoped);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.pool=persistent").unwrap();
        assert_eq!(cfg.optex.pool, PoolMode::Persistent);
        cfg.apply_override("optex.pool=scoped").unwrap();
        assert_eq!(cfg.optex.pool, PoolMode::Scoped);
        assert!(cfg.apply_override("optex.pool=rayon").is_err());
        assert_eq!(RunConfig::default().describe()["pool"], "scoped");
    }

    #[test]
    fn serve_table_parses_and_validates() {
        let doc = r#"
            workload = "ackley"

            [serve]
            addr = "0.0.0.0:9000"
            max_sessions = 16
            policy = "fair"
            ckpt_dir = "/tmp/serve_ckpt"
        "#;
        let cfg = RunConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.serve.addr, "0.0.0.0:9000");
        assert_eq!(cfg.serve.max_sessions, 16);
        assert_eq!(cfg.serve.policy, Policy::WeightedFair);
        assert_eq!(cfg.serve.ckpt_dir, PathBuf::from("/tmp/serve_ckpt"));

        let d = ServeParams::default();
        assert_eq!(d.max_sessions, 64);
        assert_eq!(d.policy, Policy::RoundRobin);

        let mut cfg = RunConfig::default();
        assert!(cfg.apply_override("serve.max_sessions=0").is_err());
        assert!(cfg.apply_override("serve.policy=lifo").is_err());
        cfg.apply_override("serve.max_sessions=2").unwrap();
        assert_eq!(cfg.serve.max_sessions, 2);
    }

    #[test]
    fn gp_refresh_every_parses_with_zero_off_default() {
        assert_eq!(RunConfig::default().optex.gp_refresh_every, 0);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.gp_refresh_every=25").unwrap();
        assert_eq!(cfg.optex.gp_refresh_every, 25);
        cfg.apply_override("optex.gp_refresh_every=0").unwrap();
        assert_eq!(cfg.optex.gp_refresh_every, 0);
        assert!(cfg.apply_override("optex.gp_refresh_every=-1").is_err());
        assert!(RunConfig::default().describe().contains_key("gp_refresh_every"));
    }

    #[test]
    fn fit_engine_parses_and_rejects_unknown() {
        assert_eq!(RunConfig::default().optex.fit, GpFit::Incremental);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.fit=full").unwrap();
        assert_eq!(cfg.optex.fit, GpFit::Full);
        cfg.apply_override("optex.fit=incremental").unwrap();
        assert_eq!(cfg.optex.fit, GpFit::Incremental);
        assert!(cfg.apply_override("optex.fit=cached").is_err());
    }

    #[test]
    fn overrides_apply_after_file() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("method=vanilla").unwrap();
        cfg.apply_override("optex.parallelism=8").unwrap();
        cfg.apply_override("optimizer.lr=0.5").unwrap();
        cfg.apply_override("workload=mnist").unwrap();
        assert_eq!(cfg.method, Method::Vanilla);
        assert_eq!(cfg.optex.parallelism, 8);
        assert!((cfg.optimizer.lr() - 0.5).abs() < 1e-12);
        assert_eq!(cfg.workload, "mnist");
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(RunConfig::from_toml("bogus = 1").is_err());
        assert!(RunConfig::from_toml("method = \"magic\"").is_err());
        assert!(RunConfig::from_toml("steps = 0").is_err());
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_override("optex.parallelism=0").is_err());
        assert!(cfg.apply_override("nokey=1").is_err());
        assert!(cfg.apply_override("justakey").is_err());
    }

    #[test]
    fn optimizer_name_preserves_lr() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("optimizer.lr=0.25").unwrap();
        cfg.apply_override("optimizer.name=sgd").unwrap();
        assert_eq!(cfg.optimizer, OptSpec::Sgd { lr: 0.25 });
    }

    #[test]
    fn describe_contains_core_fields() {
        let d = RunConfig::default().describe();
        for k in ["workload", "method", "N", "T0", "kernel"] {
            assert!(d.contains_key(k), "{k}");
        }
    }
}
