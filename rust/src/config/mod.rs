//! Typed run configuration (the framework's config system).
//!
//! Configs are TOML files (parsed by the from-scratch [`toml`] subset
//! parser) with CLI `--set key=value` overrides. Every knob of Algo. 1 and
//! of the baselines is reachable from here; `configs/*.toml` in the repo
//! root mirror the paper's Appx-B.2 experiment settings.

pub mod toml;

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::coordinator::selection::Selection;
use crate::gp::{GpFit, Kernel};
use crate::opt::{OptSpec, Schedule};
use crate::runtime::PoolMode;
use crate::serve::Policy;
use toml::Value;

/// Which iteration scheme drives the run (paper Fig. 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Algo. 1 — proxy updates on estimated gradients, then N parallel
    /// ground-truth steps.
    Optex,
    /// Standard sequential FOO (Algo. 1 with N = 1).
    Vanilla,
    /// Ideal parallelization: ground-truth gradients for the chain
    /// (impractical upper baseline).
    Target,
    /// Sample-averaging baseline (Remark 1): N gradients at the SAME
    /// point, averaged.
    DataParallel,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "optex" => Some(Method::Optex),
            "vanilla" => Some(Method::Vanilla),
            "target" => Some(Method::Target),
            "dataparallel" | "data_parallel" => Some(Method::DataParallel),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Optex => "optex",
            Method::Vanilla => "vanilla",
            Method::Target => "target",
            Method::DataParallel => "dataparallel",
        }
    }
}

/// Gradient-estimation backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// rust/src/gp (request path stays rust-only either way).
    Native,
    /// AOT gp_estimate artifact through PJRT.
    Hlo,
}

/// What the driver does when an eval fan-out returns non-finite
/// (NaN/Inf) losses or gradient rows (ISSUE 7 non-finite hygiene).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NonFinite {
    /// Fail the iteration (and hence the session) with a descriptive
    /// error. The conservative default: garbage never enters history.
    Fail,
    /// Drop the whole fan-out (abandon the arena loan), keep θ and the
    /// optimizer untouched, and record the iteration with a NaN loss.
    /// History and GP state are exactly as if the iteration never ran.
    Skip,
    /// Accept the finite points, evict every non-finite history row and
    /// force a full GP refit (epoch bump → the `NotSpd`/rebuild fallback
    /// machinery), so poisoned rows cannot contaminate later estimates.
    Resync,
}

impl NonFinite {
    pub fn parse(s: &str) -> Option<NonFinite> {
        match s {
            "fail" => Some(NonFinite::Fail),
            "skip" => Some(NonFinite::Skip),
            "resync" => Some(NonFinite::Resync),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NonFinite::Fail => "fail",
            NonFinite::Skip => "skip",
            NonFinite::Resync => "resync",
        }
    }
}

/// OptEx-specific knobs (paper Sec. 4 + Appx B.2).
#[derive(Clone, Debug, PartialEq)]
pub struct OptexParams {
    /// Parallelism N.
    pub parallelism: usize,
    /// Local-history length T₀.
    pub t0: usize,
    pub kernel: Kernel,
    /// None -> median heuristic.
    pub lengthscale: Option<f64>,
    /// Observation noise σ².
    pub sigma2: f64,
    /// Kernel dim-subset size D̃ (None -> full d).
    pub dsub: Option<usize>,
    /// θ_t selection principle (Fig. 6b): last / func / grad.
    pub selection: Selection,
    /// Evaluate intermediate gradients (Fig. 6a ablation; true = paper
    /// Algo. 1 line 7).
    pub eval_intermediate: bool,
    pub backend: Backend,
    /// GP fit engine: `incremental` (rank-1 factor up/downdates across
    /// iterations, the default) or `full` (from-scratch reference refit).
    pub fit: GpFit,
    /// Periodic factor refresh for pinned-lengthscale incremental runs:
    /// every K syncs the Cholesky factor is refactorized from the cached
    /// distances, bounding rank-1 chain drift on very long runs. 0
    /// (default) = off; no effect under the median heuristic or the
    /// `full` engine.
    pub gp_refresh_every: usize,
    /// Native compute pool width for the eval_batch fan-out and the GP
    /// hot loops. 0 = auto-detect available parallelism (default);
    /// 1 = legacy serial path (kept for differential testing).
    /// Trajectories are bit-identical at any value.
    pub threads: usize,
    /// Native pool execution substrate: `scoped` (spawn per call,
    /// default) or `persistent` (process-global parked workers — the
    /// profile for long-lived `serve` processes). Never a numerics fork:
    /// trajectories are bit-identical across modes.
    pub pool: PoolMode,
    /// Non-finite gradient/loss policy: `fail` (default) | `skip` |
    /// `resync` (ISSUE 7).
    pub on_nonfinite: NonFinite,
    /// Eval-failure retry budget per iteration: a failed
    /// `GradSource::eval_batch` fan-out is re-attempted up to this many
    /// times before the iteration (and session) fails. 0 = no retries.
    pub retry_max: usize,
    /// Linear backoff between eval retries: attempt k sleeps
    /// `k * retry_backoff_ms`. Wall-clock only — never reaches
    /// trajectories or goldens.
    pub retry_backoff_ms: u64,
    /// Per-fan-out eval deadline in seconds: an eval_batch whose wall
    /// span exceeds this counts as a failed attempt (retried per
    /// `retry_max`). 0 (default) = no deadline.
    pub eval_timeout_s: f64,
}

impl Default for OptexParams {
    fn default() -> Self {
        OptexParams {
            parallelism: 4,
            t0: 10,
            kernel: Kernel::Matern52,
            lengthscale: None,
            sigma2: 0.0,
            dsub: None,
            selection: Selection::Last,
            eval_intermediate: true,
            backend: Backend::Native,
            fit: GpFit::Incremental,
            gp_refresh_every: 0,
            threads: 0,
            pool: PoolMode::Scoped,
            on_nonfinite: NonFinite::Fail,
            retry_max: 0,
            retry_backoff_ms: 0,
            eval_timeout_s: 0.0,
        }
    }
}

/// `[serve]` table: the multi-session serving subsystem (ISSUE 4).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeParams {
    /// Listen address for the JSONL wire protocol (`host:port`; port 0
    /// binds an ephemeral port, printed at startup).
    pub addr: String,
    /// Admission cap: sessions in Pending/Running/Paused at once.
    /// Submissions beyond it are rejected at the protocol level.
    pub max_sessions: usize,
    /// Iteration scheduling policy: `rr` (deterministic round-robin,
    /// default) or `fair` (weighted-fair on the per-session eval-seconds
    /// EMA). Either way trajectories are bit-identical to solo runs —
    /// the scheduler never reorders work *within* a session.
    pub policy: Policy,
    /// Directory for checkpoint-backed suspend files of paused sessions
    /// (and the durable session manifest — ISSUE 5).
    pub ckpt_dir: PathBuf,
    /// Adopt the sessions recorded in `ckpt_dir`'s `manifest.jsonl` at
    /// startup (`--adopt`): they re-register as Paused with their
    /// original ids, budgets and configs; suspended ones `resume`
    /// bit-identically from their checkpoints. Without this flag a
    /// server refuses to start against a ckpt_dir that holds a manifest
    /// from a previous server (the session-id-reuse hazard).
    pub adopt: bool,
    /// Default push cadence for `watch` subscriptions that omit
    /// `stream_every`: an iter record every K iterations (≥ 1).
    pub stream_every: usize,
    /// Concurrent TCP connection cap: connections beyond it receive an
    /// error line and are dropped (untrusted-client hygiene, ISSUE 7).
    pub max_conns: usize,
    /// Stepper-pool width (ISSUE 8): how many sessions' quanta may run
    /// simultaneously on worker threads. 1 (default) = the serial
    /// scheduler: quanta run inline on the serve thread, one at a time.
    /// With K > 1 the Arbiter still enforces Σ grants ≤ physical across
    /// the in-flight set, so steppers adds concurrency between sessions
    /// without oversubscribing the machine. Never a numerics fork:
    /// per-session trajectories are bit-identical at any value.
    pub steppers: usize,
    /// Second listener serving the Prometheus text exposition of the
    /// server's metrics registry (ISSUE 9). `host:port` (port 0 binds an
    /// ephemeral port, printed at startup); empty (default) = metrics
    /// export off. The `stats` wire verb answers regardless.
    pub metrics_addr: String,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            addr: "127.0.0.1:7878".into(),
            max_sessions: 64,
            policy: Policy::RoundRobin,
            ckpt_dir: PathBuf::from("results/serve_ckpt"),
            adopt: false,
            stream_every: 1,
            max_conns: 256,
            steppers: 1,
            metrics_addr: String::new(),
        }
    }
}

/// `[router]` table: the multi-process scale-out front tier (ISSUE 10).
/// Like `[serve]`, router knobs are server-level: a session's driver
/// never reads them, so they are excluded from
/// [`RunConfig::overrides_from_default`] by the same reasoning.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterParams {
    /// Front listen address for the client-facing JSONL protocol
    /// (`host:port`; port 0 binds an ephemeral port, printed at
    /// startup).
    pub addr: String,
    /// How many `optex serve` worker processes the router spawns.
    pub workers: usize,
    /// Router state directory: holds `routes.jsonl` (the persisted
    /// client-id → worker placement table) and one `worker_<i>/`
    /// ckpt_dir per spawned worker — keeping worker state under the
    /// router's dir is what lets it recover a SIGKILLed worker's
    /// sessions from that worker's manifest.
    pub dir: PathBuf,
    /// Path to the `optex` binary to spawn workers from; empty
    /// (default) = the router's own executable.
    pub worker_bin: String,
    /// Retention policy for the finished-result cache: how many
    /// terminal `result` lines the router keeps after their sessions
    /// are gone from the workers (oldest evicted first). Clients can
    /// fetch a finished session's result from the router even after
    /// worker-side eviction — the serve tier's retention leftover from
    /// ISSUE 5, closed at the router.
    pub result_cache: usize,
}

impl Default for RouterParams {
    fn default() -> Self {
        RouterParams {
            addr: "127.0.0.1:7979".into(),
            workers: 2,
            dir: PathBuf::from("results/router"),
            worker_bin: String::new(),
            result_cache: 256,
        }
    }
}

/// Complete run configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Workload id: synthetic fn name, "mnist", "fmnist", "cifar",
    /// "tfm_char", or an RL env ("cartpole", ...).
    pub workload: String,
    pub method: Method,
    /// Sequential iterations T (episodes for RL).
    pub steps: usize,
    pub seed: u64,
    pub optimizer: OptSpec,
    /// Learning-rate schedule applied on top of the base lr.
    pub schedule: Schedule,
    pub optex: OptexParams,
    /// Multi-session serving knobs (`optex serve`).
    pub serve: ServeParams,
    /// Multi-process scale-out knobs (`optex router`, ISSUE 10).
    pub router: RouterParams,
    /// Extra gaussian gradient noise std for synthetic workloads (σ of
    /// Assump. 1; 0 = deterministic, paper Sec. 6.1).
    pub noise_std: f64,
    /// Synthetic-function dimension override (d).
    pub synth_dim: usize,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    /// Record metrics every k-th sequential iteration.
    pub log_every: usize,
    /// Use HLO workload oracle instead of the native one where available.
    pub hlo_workload: bool,
    /// Deterministic fault-injection plan (ISSUE 7): a `;`-separated
    /// spec of `site[:arg][@selector][*count]` clauses parsed by
    /// [`crate::faults::FaultPlan::parse`]. Empty (default) = no faults.
    /// Part of a session's identity: serialized into manifest overrides
    /// so adopted sessions keep their plan.
    pub faults: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: "rosenbrock".into(),
            method: Method::Optex,
            steps: 100,
            seed: 0,
            optimizer: OptSpec::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            schedule: Schedule::Constant,
            optex: OptexParams::default(),
            serve: ServeParams::default(),
            router: RouterParams::default(),
            noise_std: 0.0,
            synth_dim: 10_000,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            log_every: 1,
            hlo_workload: false,
            faults: String::new(),
        }
    }
}

/// Config error with the offending key.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn bad(key: &str, why: &str) -> ConfigError {
    ConfigError(format!("{key}: {why}"))
}

/// Quote a string as the right-hand side of a `--set`-style override so
/// the TOML value grammar re-types nothing (`workload=7` would become the
/// integer 7; `workload="7"` stays the string). Returns `None` for
/// control characters the grammar's escape set (`\n`, `\t`, `\"`, `\\`)
/// cannot represent.
pub fn quote_toml_str(s: &str) -> Option<String> {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => return None,
            c => out.push(c),
        }
    }
    out.push('"');
    Some(out)
}

impl RunConfig {
    /// Parse a TOML document, starting from defaults.
    pub fn from_toml(text: &str) -> Result<RunConfig, ConfigError> {
        let map = toml::parse(text).map_err(|e| ConfigError(e.to_string()))?;
        let mut cfg = RunConfig::default();
        for (k, v) in &map {
            cfg.apply(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `--set key=value` CLI overrides after file parsing.
    pub fn apply_override(&mut self, kv: &str) -> Result<(), ConfigError> {
        let (k, raw) = kv
            .split_once('=')
            .ok_or_else(|| bad(kv, "override must be key=value"))?;
        // Reuse the TOML value grammar for the right-hand side; bare words
        // (e.g. `workload=mnist`) are treated as strings.
        let v = toml::parse(&format!("x = {raw}"))
            .map(|m| m["x"].clone())
            .unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.apply(k.trim(), &v)?;
        self.validate()
    }

    /// Apply one already-parsed `(key, value)` pair — the scenario
    /// harness's entry point: scenario files carry typed TOML values, so
    /// round-tripping them through the `--set` string grammar would be a
    /// lossy detour. Validation stays with the caller (who applies many
    /// keys and validates once).
    pub fn apply_value(&mut self, key: &str, v: &Value) -> Result<(), ConfigError> {
        self.apply(key, v)
    }

    fn apply(&mut self, key: &str, v: &Value) -> Result<(), ConfigError> {
        let need_str = || v.as_str().ok_or_else(|| bad(key, "expected string"));
        let need_f64 = || v.as_f64().ok_or_else(|| bad(key, "expected number"));
        let need_usize = || v.as_usize().ok_or_else(|| bad(key, "expected non-negative integer"));
        let need_bool = || v.as_bool().ok_or_else(|| bad(key, "expected bool"));
        match key {
            "workload" => self.workload = need_str()?.to_string(),
            "method" => {
                self.method = Method::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown method"))?
            }
            "steps" => self.steps = need_usize()?,
            "seed" => self.seed = need_usize()? as u64,
            "noise_std" => self.noise_std = need_f64()?,
            "synth_dim" => self.synth_dim = need_usize()?,
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(need_str()?),
            "out_dir" => self.out_dir = PathBuf::from(need_str()?),
            "log_every" => self.log_every = need_usize()?.max(1),
            "hlo_workload" => self.hlo_workload = need_bool()?,
            "optimizer.name" => {
                let lr = self.optimizer.lr();
                self.optimizer = OptSpec::parse(need_str()?, lr)
                    .ok_or_else(|| bad(key, "unknown optimizer"))?;
            }
            "optimizer.schedule" => {
                self.schedule = Schedule::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown schedule (constant|warmup:K|step:K:G|cosine:H:F|theory:N:T)"))?;
            }
            "optimizer.lr" => {
                let lr = need_f64()?;
                self.optimizer = OptSpec::parse(self.optimizer.name(), lr)
                    .expect("known optimizer name");
            }
            "optex.parallelism" => self.optex.parallelism = need_usize()?,
            "optex.t0" => self.optex.t0 = need_usize()?,
            "optex.kernel" => {
                self.optex.kernel = Kernel::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown kernel"))?
            }
            "optex.lengthscale" => {
                let l = need_f64()?;
                self.optex.lengthscale = if l > 0.0 { Some(l) } else { None };
            }
            "optex.sigma2" => self.optex.sigma2 = need_f64()?,
            "optex.dsub" => {
                let d = need_usize()?;
                self.optex.dsub = if d > 0 { Some(d) } else { None };
            }
            "optex.selection" => {
                self.optex.selection = Selection::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown selection principle"))?
            }
            "optex.eval_intermediate" => self.optex.eval_intermediate = need_bool()?,
            "optex.backend" => {
                self.optex.backend = match need_str()? {
                    "native" => Backend::Native,
                    "hlo" => Backend::Hlo,
                    other => return Err(bad(key, &format!("unknown backend {other:?}"))),
                }
            }
            "optex.fit" => {
                self.optex.fit = GpFit::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown fit engine (full|incremental)"))?
            }
            "optex.gp_refresh_every" => self.optex.gp_refresh_every = need_usize()?,
            "optex.threads" => self.optex.threads = need_usize()?,
            "optex.pool" => {
                self.optex.pool = PoolMode::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown pool mode (scoped|persistent)"))?
            }
            "optex.on_nonfinite" => {
                self.optex.on_nonfinite = NonFinite::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown non-finite policy (fail|skip|resync)"))?
            }
            "optex.retry_max" => self.optex.retry_max = need_usize()?,
            "optex.retry_backoff_ms" => self.optex.retry_backoff_ms = need_usize()? as u64,
            "optex.eval_timeout_s" => self.optex.eval_timeout_s = need_f64()?,
            "faults" => self.faults = need_str()?.to_string(),
            "serve.addr" => self.serve.addr = need_str()?.to_string(),
            "serve.max_sessions" => self.serve.max_sessions = need_usize()?,
            "serve.policy" => {
                self.serve.policy = Policy::parse(need_str()?)
                    .ok_or_else(|| bad(key, "unknown serve policy (rr|fair)"))?
            }
            "serve.ckpt_dir" => self.serve.ckpt_dir = PathBuf::from(need_str()?),
            "serve.adopt" => self.serve.adopt = need_bool()?,
            "serve.stream_every" => self.serve.stream_every = need_usize()?,
            "serve.max_conns" => self.serve.max_conns = need_usize()?,
            "serve.steppers" => self.serve.steppers = need_usize()?,
            "serve.metrics_addr" => self.serve.metrics_addr = need_str()?.to_string(),
            "router.addr" => self.router.addr = need_str()?.to_string(),
            "router.workers" => self.router.workers = need_usize()?,
            "router.dir" => self.router.dir = PathBuf::from(need_str()?),
            "router.worker_bin" => self.router.worker_bin = need_str()?.to_string(),
            "router.result_cache" => self.router.result_cache = need_usize()?,
            _ => return Err(bad(key, "unknown config key")),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.optex.parallelism == 0 {
            return Err(bad("optex.parallelism", "must be >= 1"));
        }
        if self.optex.t0 == 0 {
            return Err(bad("optex.t0", "must be >= 1"));
        }
        if self.steps == 0 {
            return Err(bad("steps", "must be >= 1"));
        }
        if self.optex.sigma2 < 0.0 {
            return Err(bad("optex.sigma2", "must be >= 0"));
        }
        if self.noise_std < 0.0 {
            return Err(bad("noise_std", "must be >= 0"));
        }
        if self.synth_dim == 0 {
            return Err(bad("synth_dim", "must be >= 1"));
        }
        if self.serve.max_sessions == 0 {
            return Err(bad("serve.max_sessions", "must be >= 1"));
        }
        if self.serve.addr.is_empty() {
            return Err(bad("serve.addr", "must be host:port"));
        }
        if self.serve.stream_every == 0 {
            return Err(bad("serve.stream_every", "must be >= 1"));
        }
        if self.serve.max_conns == 0 {
            return Err(bad("serve.max_conns", "must be >= 1"));
        }
        if self.serve.steppers == 0 {
            return Err(bad("serve.steppers", "must be >= 1"));
        }
        if self.router.addr.is_empty() {
            return Err(bad("router.addr", "must be host:port"));
        }
        if self.router.workers == 0 {
            return Err(bad("router.workers", "must be >= 1"));
        }
        if self.router.result_cache == 0 {
            return Err(bad("router.result_cache", "must be >= 1"));
        }
        if !self.optex.eval_timeout_s.is_finite() || self.optex.eval_timeout_s < 0.0 {
            return Err(bad("optex.eval_timeout_s", "must be >= 0"));
        }
        if let Err(e) = crate::faults::FaultPlan::parse(&self.faults) {
            return Err(bad("faults", &format!("{e:#}")));
        }
        Ok(())
    }

    /// Serialize this config as the minimal list of `key=value` override
    /// strings that rebuild it from [`RunConfig::default`] via
    /// [`RunConfig::apply_override`] — the serve manifest's config
    /// encoding (ISSUE 5): a session persisted this way re-registers on
    /// an adopting server with exactly its submit-time config, whatever
    /// base config that server was started with.
    ///
    /// Coverage contract: every field the workload factory / driver read
    /// is representable (enforced by the round-trip property test in
    /// `serve/manifest.rs`). Two documented exceptions, both unreachable
    /// through the override grammar itself: non-default optimizer
    /// β/ε hyperparameters (the grammar only speaks `optimizer.name` +
    /// `optimizer.lr`, so wire-submitted sessions can never hold them)
    /// and the `[serve]` / `[router]` tables (server-level knobs — a
    /// session's driver never reads them, and a migrated session must
    /// not drag its source server's topology along).
    pub fn overrides_from_default(&self) -> Result<Vec<String>, ConfigError> {
        let d = RunConfig::default();
        let mut out = Vec::new();
        fn push_quoted(
            out: &mut Vec<String>,
            key: &str,
            v: &str,
        ) -> Result<(), ConfigError> {
            match quote_toml_str(v) {
                Some(q) => {
                    out.push(format!("{key}={q}"));
                    Ok(())
                }
                None => Err(bad(key, "string contains unencodable control characters")),
            }
        }
        if self.workload != d.workload {
            push_quoted(&mut out, "workload", &self.workload)?;
        }
        if self.method != d.method {
            out.push(format!("method={}", self.method.name()));
        }
        if self.steps != d.steps {
            out.push(format!("steps={}", self.steps));
        }
        if self.seed != d.seed {
            out.push(format!("seed={}", self.seed));
        }
        if self.optimizer != d.optimizer {
            out.push(format!("optimizer.name={}", self.optimizer.name()));
            out.push(format!("optimizer.lr={}", self.optimizer.lr()));
        }
        if self.schedule != d.schedule {
            out.push(format!("optimizer.schedule={}", self.schedule.spec()));
        }
        let o = &self.optex;
        let od = &d.optex;
        if o.parallelism != od.parallelism {
            out.push(format!("optex.parallelism={}", o.parallelism));
        }
        if o.t0 != od.t0 {
            out.push(format!("optex.t0={}", o.t0));
        }
        if o.kernel != od.kernel {
            out.push(format!("optex.kernel={}", o.kernel.name()));
        }
        if o.lengthscale != od.lengthscale {
            // stored Some(l) always has l > 0 (apply() maps l <= 0 to None)
            out.push(format!("optex.lengthscale={}", o.lengthscale.unwrap_or(0.0)));
        }
        if o.sigma2 != od.sigma2 {
            out.push(format!("optex.sigma2={}", o.sigma2));
        }
        if o.dsub != od.dsub {
            out.push(format!("optex.dsub={}", o.dsub.unwrap_or(0)));
        }
        if o.selection != od.selection {
            out.push(format!("optex.selection={}", o.selection.name()));
        }
        if o.eval_intermediate != od.eval_intermediate {
            out.push(format!("optex.eval_intermediate={}", o.eval_intermediate));
        }
        if o.backend != od.backend {
            let b = match o.backend {
                Backend::Native => "native",
                Backend::Hlo => "hlo",
            };
            out.push(format!("optex.backend={b}"));
        }
        if o.fit != od.fit {
            out.push(format!("optex.fit={}", o.fit.name()));
        }
        if o.gp_refresh_every != od.gp_refresh_every {
            out.push(format!("optex.gp_refresh_every={}", o.gp_refresh_every));
        }
        if o.threads != od.threads {
            out.push(format!("optex.threads={}", o.threads));
        }
        if o.pool != od.pool {
            out.push(format!("optex.pool={}", o.pool.name()));
        }
        if o.on_nonfinite != od.on_nonfinite {
            out.push(format!("optex.on_nonfinite={}", o.on_nonfinite.name()));
        }
        if o.retry_max != od.retry_max {
            out.push(format!("optex.retry_max={}", o.retry_max));
        }
        if o.retry_backoff_ms != od.retry_backoff_ms {
            out.push(format!("optex.retry_backoff_ms={}", o.retry_backoff_ms));
        }
        if o.eval_timeout_s != od.eval_timeout_s {
            out.push(format!("optex.eval_timeout_s={}", o.eval_timeout_s));
        }
        if self.noise_std != d.noise_std {
            out.push(format!("noise_std={}", self.noise_std));
        }
        if self.synth_dim != d.synth_dim {
            out.push(format!("synth_dim={}", self.synth_dim));
        }
        if self.artifacts_dir != d.artifacts_dir {
            push_quoted(&mut out, "artifacts_dir", &self.artifacts_dir.to_string_lossy())?;
        }
        if self.out_dir != d.out_dir {
            push_quoted(&mut out, "out_dir", &self.out_dir.to_string_lossy())?;
        }
        if self.log_every != d.log_every {
            out.push(format!("log_every={}", self.log_every));
        }
        if self.hlo_workload != d.hlo_workload {
            out.push(format!("hlo_workload={}", self.hlo_workload));
        }
        if self.faults != d.faults {
            // quoting matters: fault specs carry `@` / `*` / `;`, which
            // the bare-word fallback would survive, but `:` arguments
            // must not be re-typed by the TOML value grammar
            push_quoted(&mut out, "faults", &self.faults)?;
        }
        Ok(out)
    }

    /// Flatten back to key/value pairs (for run provenance records).
    pub fn describe(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        m.insert("workload".into(), self.workload.clone());
        m.insert("method".into(), self.method.name().into());
        m.insert("steps".into(), self.steps.to_string());
        m.insert("seed".into(), self.seed.to_string());
        m.insert("optimizer".into(), self.optimizer.name().into());
        m.insert("lr".into(), format!("{}", self.optimizer.lr()));
        m.insert("schedule".into(), format!("{:?}", self.schedule));
        m.insert("N".into(), self.optex.parallelism.to_string());
        m.insert("T0".into(), self.optex.t0.to_string());
        m.insert("kernel".into(), self.optex.kernel.name().into());
        m.insert("sigma2".into(), format!("{}", self.optex.sigma2));
        m.insert("selection".into(), self.optex.selection.name().into());
        m.insert("fit".into(), self.optex.fit.name().into());
        m.insert("gp_refresh_every".into(), self.optex.gp_refresh_every.to_string());
        m.insert("threads".into(), self.optex.threads.to_string());
        m.insert("pool".into(), self.optex.pool.name().into());
        m.insert("on_nonfinite".into(), self.optex.on_nonfinite.name().into());
        m.insert("retry_max".into(), self.optex.retry_max.to_string());
        if !self.faults.is_empty() {
            m.insert("faults".into(), self.faults.clone());
        }
        m.insert("noise_std".into(), format!("{}", self.noise_std));
        m.insert("synth_dim".into(), self.synth_dim.to_string());
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn full_document_roundtrip() {
        let doc = r#"
            workload = "sphere"
            method = "target"
            steps = 50
            seed = 3
            noise_std = 0.1
            synth_dim = 1000

            [optimizer]
            name = "sgd"
            lr = 0.01

            [optex]
            parallelism = 5
            t0 = 20
            kernel = "rbf"
            sigma2 = 0.05
            dsub = 256
            selection = "func"
            eval_intermediate = false
            backend = "native"
            fit = "full"
        "#;
        let cfg = RunConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.workload, "sphere");
        assert_eq!(cfg.method, Method::Target);
        assert_eq!(cfg.optimizer, OptSpec::Sgd { lr: 0.01 });
        assert_eq!(cfg.optex.parallelism, 5);
        assert_eq!(cfg.optex.kernel, Kernel::Rbf);
        assert_eq!(cfg.optex.dsub, Some(256));
        assert!(!cfg.optex.eval_intermediate);
        assert_eq!(cfg.optex.selection, Selection::Func);
        assert_eq!(cfg.optex.fit, GpFit::Full);
    }

    #[test]
    fn threads_knob_parses_with_zero_as_auto_default() {
        assert_eq!(RunConfig::default().optex.threads, 0);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.threads=8").unwrap();
        assert_eq!(cfg.optex.threads, 8);
        cfg.apply_override("optex.threads=1").unwrap();
        assert_eq!(cfg.optex.threads, 1);
        assert!(cfg.apply_override("optex.threads=-2").is_err());
        assert!(RunConfig::default().describe().contains_key("threads"));
    }

    #[test]
    fn pool_mode_knob_parses_with_scoped_default() {
        assert_eq!(RunConfig::default().optex.pool, PoolMode::Scoped);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.pool=persistent").unwrap();
        assert_eq!(cfg.optex.pool, PoolMode::Persistent);
        cfg.apply_override("optex.pool=scoped").unwrap();
        assert_eq!(cfg.optex.pool, PoolMode::Scoped);
        assert!(cfg.apply_override("optex.pool=rayon").is_err());
        assert_eq!(RunConfig::default().describe()["pool"], "scoped");
    }

    #[test]
    fn serve_table_parses_and_validates() {
        let doc = r#"
            workload = "ackley"

            [serve]
            addr = "0.0.0.0:9000"
            max_sessions = 16
            policy = "fair"
            ckpt_dir = "/tmp/serve_ckpt"
        "#;
        let cfg = RunConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.serve.addr, "0.0.0.0:9000");
        assert_eq!(cfg.serve.max_sessions, 16);
        assert_eq!(cfg.serve.policy, Policy::WeightedFair);
        assert_eq!(cfg.serve.ckpt_dir, PathBuf::from("/tmp/serve_ckpt"));

        let d = ServeParams::default();
        assert_eq!(d.max_sessions, 64);
        assert_eq!(d.policy, Policy::RoundRobin);

        let mut cfg = RunConfig::default();
        assert!(cfg.apply_override("serve.max_sessions=0").is_err());
        assert!(cfg.apply_override("serve.policy=lifo").is_err());
        cfg.apply_override("serve.max_sessions=2").unwrap();
        assert_eq!(cfg.serve.max_sessions, 2);
    }

    #[test]
    fn router_table_parses_and_validates() {
        let doc = r#"
            workload = "ackley"

            [router]
            addr = "0.0.0.0:9100"
            workers = 4
            dir = "/tmp/router"
            worker_bin = "/usr/local/bin/optex"
            result_cache = 32
        "#;
        let cfg = RunConfig::from_toml(doc).unwrap();
        assert_eq!(cfg.router.addr, "0.0.0.0:9100");
        assert_eq!(cfg.router.workers, 4);
        assert_eq!(cfg.router.dir, PathBuf::from("/tmp/router"));
        assert_eq!(cfg.router.worker_bin, "/usr/local/bin/optex");
        assert_eq!(cfg.router.result_cache, 32);

        let d = RouterParams::default();
        assert_eq!(d.workers, 2);
        assert_eq!(d.result_cache, 256);
        assert!(d.worker_bin.is_empty(), "default = the router's own binary");

        let mut cfg = RunConfig::default();
        assert!(cfg.apply_override("router.workers=0").is_err());
        assert!(cfg.apply_override("router.result_cache=0").is_err());
        assert!(cfg.apply_override("router.addr=\"\"").is_err());
        cfg.apply_override("router.workers=3").unwrap();
        assert_eq!(cfg.router.workers, 3);
    }

    #[test]
    fn router_table_is_excluded_from_manifest_overrides() {
        // like [serve]: server-level topology must not travel with a
        // migrated session's config
        let mut cfg = RunConfig::default();
        cfg.apply_override("router.workers=5").unwrap();
        cfg.apply_override("workload=\"sphere\"").unwrap();
        let ovs = cfg.overrides_from_default().unwrap();
        assert!(
            ovs.iter().all(|kv| !kv.starts_with("router.")),
            "router keys leaked into manifest overrides: {ovs:?}"
        );
        assert!(ovs.iter().any(|kv| kv.starts_with("workload=")));
    }

    #[test]
    fn serve_metrics_addr_knob_defaults_off() {
        assert_eq!(ServeParams::default().metrics_addr, "");
        let mut cfg = RunConfig::default();
        cfg.apply_override("serve.metrics_addr=\"127.0.0.1:9102\"").unwrap();
        assert_eq!(cfg.serve.metrics_addr, "127.0.0.1:9102");
        cfg.validate().unwrap();
    }

    #[test]
    fn serve_adopt_and_stream_every_knobs() {
        let d = ServeParams::default();
        assert!(!d.adopt);
        assert_eq!(d.stream_every, 1);
        let mut cfg = RunConfig::default();
        cfg.apply_override("serve.adopt=true").unwrap();
        assert!(cfg.serve.adopt);
        cfg.apply_override("serve.stream_every=5").unwrap();
        assert_eq!(cfg.serve.stream_every, 5);
        assert!(cfg.apply_override("serve.stream_every=0").is_err());
        assert!(cfg.apply_override("serve.adopt=maybe").is_err());
    }

    #[test]
    fn overrides_from_default_roundtrip() {
        let mut cfg = RunConfig::default();
        for kv in [
            "workload=ackley",
            "method=target",
            "steps=77",
            "seed=9",
            "optimizer.name=sgd",
            "optimizer.lr=0.025",
            "optimizer.schedule=step:10:0.5",
            "optex.parallelism=6",
            "optex.t0=12",
            "optex.kernel=rbf",
            "optex.lengthscale=3.5",
            "optex.sigma2=0.125",
            "optex.dsub=128",
            "optex.selection=func",
            "optex.eval_intermediate=false",
            "optex.fit=full",
            "optex.gp_refresh_every=25",
            "optex.threads=8",
            "optex.pool=persistent",
            "optex.on_nonfinite=resync",
            "optex.retry_max=2",
            "optex.retry_backoff_ms=5",
            "optex.eval_timeout_s=0.5",
            "noise_std=0.3",
            "synth_dim=512",
            "out_dir=\"res 2024\"",
            "log_every=2",
            "faults=\"eval_err@s1.i3*2; nan_row@s1.i5.p0\"",
        ] {
            cfg.apply_override(kv).unwrap();
        }
        let ovs = cfg.overrides_from_default().unwrap();
        let mut back = RunConfig::default();
        for kv in &ovs {
            back.apply_override(kv).unwrap();
        }
        assert_eq!(back, cfg, "overrides did not rebuild the config: {ovs:?}");
        // defaults serialize to NO overrides (minimal encoding)
        assert!(RunConfig::default().overrides_from_default().unwrap().is_empty());
    }

    #[test]
    fn quote_toml_str_roundtrips_through_the_override_grammar() {
        for s in ["plain", "7", "res 2024", "a\"b\\c", "tab\there", "nl\nthere", ""] {
            let q = quote_toml_str(s).unwrap();
            let mut cfg = RunConfig::default();
            cfg.apply_override(&format!("workload={q}")).unwrap();
            assert_eq!(cfg.workload, s, "quoted as {q}");
        }
        assert!(quote_toml_str("bell\u{7}").is_none());
    }

    #[test]
    fn gp_refresh_every_parses_with_zero_off_default() {
        assert_eq!(RunConfig::default().optex.gp_refresh_every, 0);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.gp_refresh_every=25").unwrap();
        assert_eq!(cfg.optex.gp_refresh_every, 25);
        cfg.apply_override("optex.gp_refresh_every=0").unwrap();
        assert_eq!(cfg.optex.gp_refresh_every, 0);
        assert!(cfg.apply_override("optex.gp_refresh_every=-1").is_err());
        assert!(RunConfig::default().describe().contains_key("gp_refresh_every"));
    }

    #[test]
    fn fit_engine_parses_and_rejects_unknown() {
        assert_eq!(RunConfig::default().optex.fit, GpFit::Incremental);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.fit=full").unwrap();
        assert_eq!(cfg.optex.fit, GpFit::Full);
        cfg.apply_override("optex.fit=incremental").unwrap();
        assert_eq!(cfg.optex.fit, GpFit::Incremental);
        assert!(cfg.apply_override("optex.fit=cached").is_err());
    }

    #[test]
    fn overrides_apply_after_file() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("method=vanilla").unwrap();
        cfg.apply_override("optex.parallelism=8").unwrap();
        cfg.apply_override("optimizer.lr=0.5").unwrap();
        cfg.apply_override("workload=mnist").unwrap();
        assert_eq!(cfg.method, Method::Vanilla);
        assert_eq!(cfg.optex.parallelism, 8);
        assert!((cfg.optimizer.lr() - 0.5).abs() < 1e-12);
        assert_eq!(cfg.workload, "mnist");
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(RunConfig::from_toml("bogus = 1").is_err());
        assert!(RunConfig::from_toml("method = \"magic\"").is_err());
        assert!(RunConfig::from_toml("steps = 0").is_err());
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_override("optex.parallelism=0").is_err());
        assert!(cfg.apply_override("nokey=1").is_err());
        assert!(cfg.apply_override("justakey").is_err());
    }

    #[test]
    fn optimizer_name_preserves_lr() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("optimizer.lr=0.25").unwrap();
        cfg.apply_override("optimizer.name=sgd").unwrap();
        assert_eq!(cfg.optimizer, OptSpec::Sgd { lr: 0.25 });
    }

    #[test]
    fn nonfinite_and_retry_knobs_parse_and_reject() {
        let d = OptexParams::default();
        assert_eq!(d.on_nonfinite, NonFinite::Fail);
        assert_eq!(d.retry_max, 0);
        assert_eq!(d.retry_backoff_ms, 0);
        assert_eq!(d.eval_timeout_s, 0.0);
        let mut cfg = RunConfig::default();
        cfg.apply_override("optex.on_nonfinite=skip").unwrap();
        assert_eq!(cfg.optex.on_nonfinite, NonFinite::Skip);
        cfg.apply_override("optex.on_nonfinite=resync").unwrap();
        assert_eq!(cfg.optex.on_nonfinite, NonFinite::Resync);
        assert!(cfg.apply_override("optex.on_nonfinite=panic").is_err());
        cfg.apply_override("optex.retry_max=3").unwrap();
        cfg.apply_override("optex.retry_backoff_ms=10").unwrap();
        cfg.apply_override("optex.eval_timeout_s=0.25").unwrap();
        assert_eq!(cfg.optex.retry_max, 3);
        assert_eq!(cfg.optex.retry_backoff_ms, 10);
        assert_eq!(cfg.optex.eval_timeout_s, 0.25);
        assert!(cfg.apply_override("optex.eval_timeout_s=-1.0").is_err());
        assert!(RunConfig::default().describe().contains_key("on_nonfinite"));
    }

    #[test]
    fn faults_spec_validates_through_the_plan_parser() {
        let mut cfg = RunConfig::default();
        assert!(cfg.faults.is_empty());
        cfg.apply_override("faults=\"eval_panic@s2.i4\"").unwrap();
        assert_eq!(cfg.faults, "eval_panic@s2.i4");
        // bare-word fallback also works for selector-free specs
        cfg.apply_override("faults=eval_err*0").unwrap();
        assert_eq!(cfg.faults, "eval_err*0");
        // a malformed spec is rejected at validate() time with the key
        let err = cfg.apply_override("faults=\"made_up_site@i1\"").unwrap_err();
        assert!(err.to_string().contains("faults"), "{err}");
    }

    #[test]
    fn serve_max_conns_knob() {
        assert_eq!(ServeParams::default().max_conns, 256);
        let mut cfg = RunConfig::default();
        cfg.apply_override("serve.max_conns=2").unwrap();
        assert_eq!(cfg.serve.max_conns, 2);
        assert!(cfg.apply_override("serve.max_conns=0").is_err());
    }

    #[test]
    fn serve_steppers_knob_defaults_to_serial() {
        assert_eq!(ServeParams::default().steppers, 1);
        let mut cfg = RunConfig::default();
        cfg.apply_override("serve.steppers=4").unwrap();
        assert_eq!(cfg.serve.steppers, 4);
        cfg.apply_override("serve.steppers=1").unwrap();
        assert_eq!(cfg.serve.steppers, 1);
        assert!(cfg.apply_override("serve.steppers=0").is_err());
        assert!(cfg.apply_override("serve.steppers=-1").is_err());
    }

    #[test]
    fn describe_contains_core_fields() {
        let d = RunConfig::default().describe();
        for k in ["workload", "method", "N", "T0", "kernel"] {
            assert!(d.contains_key(k), "{k}");
        }
    }
}
