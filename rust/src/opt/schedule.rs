//! Learning-rate schedules (framework feature; the paper's Thm-2 η is a
//! horizon-dependent constant — `Schedule::Theory` implements exactly
//! that choice, the others are the standard training schedules).

/// A learning-rate schedule: maps sequential iteration t (1-based) to a
/// multiplier on the base learning rate.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// Constant multiplier 1.
    Constant,
    /// Linear warmup over `warmup` iterations, then constant.
    Warmup { warmup: usize },
    /// Step decay: ×`gamma` every `every` iterations.
    Step { every: usize, gamma: f64 },
    /// Cosine annealing from 1 to `floor` over `horizon` iterations.
    Cosine { horizon: usize, floor: f64 },
    /// Thm-2's η ∝ 1/√(N·T): constant per run, but scaled by the
    /// (N, T) the run was configured with relative to (1, T).
    Theory { n: usize, t: usize },
}

impl Schedule {
    /// Parse "constant", "warmup:100", "step:200:0.5",
    /// "cosine:1000:0.01", "theory:4:500".
    pub fn parse(s: &str) -> Option<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["constant"] => Some(Schedule::Constant),
            ["warmup", w] => Some(Schedule::Warmup { warmup: w.parse().ok()? }),
            ["step", e, g] => Some(Schedule::Step {
                every: e.parse().ok()?,
                gamma: g.parse().ok()?,
            }),
            ["cosine", h, f] => Some(Schedule::Cosine {
                horizon: h.parse().ok()?,
                floor: f.parse().ok()?,
            }),
            ["theory", n, t] => Some(Schedule::Theory {
                n: n.parse().ok()?,
                t: t.parse().ok()?,
            }),
            _ => None,
        }
    }

    /// The spec string [`Schedule::parse`] accepts — `parse(spec())`
    /// round-trips exactly (f64 params print shortest-roundtrip), which
    /// is what lets the serve manifest persist a session's schedule as a
    /// plain `optimizer.schedule=...` override (ISSUE 5).
    pub fn spec(&self) -> String {
        match *self {
            Schedule::Constant => "constant".into(),
            Schedule::Warmup { warmup } => format!("warmup:{warmup}"),
            Schedule::Step { every, gamma } => format!("step:{every}:{gamma}"),
            Schedule::Cosine { horizon, floor } => format!("cosine:{horizon}:{floor}"),
            Schedule::Theory { n, t } => format!("theory:{n}:{t}"),
        }
    }

    /// Multiplier at iteration `t` (1-based).
    pub fn factor(&self, t: usize) -> f64 {
        match *self {
            Schedule::Constant => 1.0,
            Schedule::Warmup { warmup } => {
                if warmup == 0 || t >= warmup {
                    1.0
                } else {
                    t as f64 / warmup as f64
                }
            }
            Schedule::Step { every, gamma } => {
                if every == 0 {
                    1.0
                } else {
                    gamma.powi(((t.saturating_sub(1)) / every) as i32)
                }
            }
            Schedule::Cosine { horizon, floor } => {
                if horizon == 0 {
                    return 1.0;
                }
                let p = ((t.saturating_sub(1)) as f64 / horizon as f64).min(1.0);
                floor + (1.0 - floor) * 0.5 * (1.0 + (std::f64::consts::PI * p).cos())
            }
            Schedule::Theory { n, t: horizon } => {
                // η = sqrt(2Δ / (N T L σ² ρ)) — all constants fold into
                // the base lr; relative to the (N=1, T) run the factor is
                // 1/sqrt(N) (same T), matching Thm 2's choice.
                let _ = horizon;
                1.0 / (n.max(1) as f64).sqrt()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Schedule::parse("constant"), Some(Schedule::Constant));
        assert_eq!(
            Schedule::parse("warmup:10"),
            Some(Schedule::Warmup { warmup: 10 })
        );
        assert_eq!(
            Schedule::parse("step:100:0.5"),
            Some(Schedule::Step { every: 100, gamma: 0.5 })
        );
        assert_eq!(
            Schedule::parse("cosine:50:0.1"),
            Some(Schedule::Cosine { horizon: 50, floor: 0.1 })
        );
        assert_eq!(Schedule::parse("theory:4:100"), Some(Schedule::Theory { n: 4, t: 100 }));
        assert_eq!(Schedule::parse("linear"), None);
        assert_eq!(Schedule::parse("warmup:x"), None);
    }

    #[test]
    fn spec_string_roundtrips_every_variant() {
        for s in [
            Schedule::Constant,
            Schedule::Warmup { warmup: 12 },
            Schedule::Step { every: 100, gamma: 0.5 },
            Schedule::Step { every: 3, gamma: 0.1 + 0.2 }, // non-terminating repr
            Schedule::Cosine { horizon: 1000, floor: 0.0123 },
            Schedule::Theory { n: 4, t: 500 },
        ] {
            assert_eq!(Schedule::parse(&s.spec()), Some(s.clone()), "{}", s.spec());
        }
    }

    #[test]
    fn warmup_ramps_then_flat() {
        let s = Schedule::Warmup { warmup: 4 };
        assert!((s.factor(1) - 0.25).abs() < 1e-12);
        assert!((s.factor(2) - 0.5).abs() < 1e-12);
        assert_eq!(s.factor(4), 1.0);
        assert_eq!(s.factor(100), 1.0);
    }

    #[test]
    fn step_decays_in_stages() {
        let s = Schedule::Step { every: 10, gamma: 0.5 };
        assert_eq!(s.factor(1), 1.0);
        assert_eq!(s.factor(10), 1.0);
        assert_eq!(s.factor(11), 0.5);
        assert_eq!(s.factor(21), 0.25);
    }

    #[test]
    fn cosine_monotone_to_floor() {
        let s = Schedule::Cosine { horizon: 100, floor: 0.1 };
        assert!((s.factor(1) - 1.0).abs() < 1e-6);
        let mid = s.factor(51);
        assert!(mid < 1.0 && mid > 0.1);
        assert!((s.factor(101) - 0.1).abs() < 1e-9);
        assert!((s.factor(500) - 0.1).abs() < 1e-9); // clamps past horizon
        let mut last = 1.1;
        for t in 1..=101 {
            let f = s.factor(t);
            assert!(f <= last + 1e-12, "not monotone at {t}");
            last = f;
        }
    }

    #[test]
    fn theory_is_inverse_sqrt_n() {
        let s = Schedule::Theory { n: 4, t: 100 };
        assert!((s.factor(1) - 0.5).abs() < 1e-12);
        assert_eq!(s.factor(1), s.factor(99)); // constant over the run
    }

    #[test]
    fn degenerate_params_are_safe() {
        assert_eq!(Schedule::Warmup { warmup: 0 }.factor(1), 1.0);
        assert_eq!(Schedule::Step { every: 0, gamma: 0.5 }.factor(5), 1.0);
        assert_eq!(Schedule::Cosine { horizon: 0, floor: 0.5 }.factor(3), 1.0);
        assert_eq!(Schedule::Theory { n: 0, t: 0 }.factor(1), 1.0);
    }
}
