//! First-order optimizers (the FO-OPT plug-ins of Algo. 1).
//!
//! The paper instantiates OptEx with SGD (theory + NN training) and Adam
//! (synthetic + RL experiments); the rest are standard FOO algorithms from
//! its Related Work that slot into the same trait, demonstrating the
//! "general framework" claim.
//!
//! OptEx-specific requirement: the proxy chain advances optimizer state
//! *speculatively* on estimated gradients, and each parallel worker `i`
//! resumes from the state snapshot after `i−1` proxy steps (DESIGN.md
//! §Semantics). Hence [`Optimizer::clone_box`] — state must be cheaply
//! snapshot-able.

mod adagrad;
mod adam;
mod momentum;
mod schedule;
mod sgd;

pub use adagrad::AdaGrad;
pub use adam::{AdaBelief, Adam};
pub use momentum::Momentum;
pub use schedule::Schedule;
pub use sgd::Sgd;

/// A stateful first-order update rule θ ← FO-OPT(θ, g).
pub trait Optimizer: Send {
    /// Apply one update in place. `grad.len() == params.len()`.
    fn step(&mut self, params: &mut [f32], grad: &[f32]);

    /// Snapshot the full optimizer state (used by the proxy chain).
    fn clone_box(&self) -> Box<dyn Optimizer>;

    fn name(&self) -> &'static str;

    /// Current base learning rate.
    fn lr(&self) -> f64;

    /// Override the base learning rate (used by lr sweeps / Thm-2 η).
    fn set_lr(&mut self, lr: f64);

    /// Serialize internal state buffers (moment vectors, step counters —
    /// NOT the hyperparameters) for checkpointing. Stateless optimizers
    /// return an empty vec.
    fn save_state(&self) -> Vec<Vec<f32>> {
        Vec::new()
    }

    /// Restore state saved by [`Optimizer::save_state`] from a matching
    /// optimizer configuration. Errs on arity/shape mismatch.
    fn load_state(&mut self, state: &[Vec<f32>]) -> Result<(), String> {
        if state.is_empty() {
            Ok(())
        } else {
            Err(format!("{}: unexpected state buffers", self.name()))
        }
    }
}

impl Clone for Box<dyn Optimizer> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Declarative optimizer spec (parsed from configs / CLI).
#[derive(Clone, Debug, PartialEq)]
pub enum OptSpec {
    Sgd { lr: f64 },
    Momentum { lr: f64, beta: f64, nesterov: bool },
    Adam { lr: f64, beta1: f64, beta2: f64, eps: f64 },
    AdaGrad { lr: f64, eps: f64 },
    AdaBelief { lr: f64, beta1: f64, beta2: f64, eps: f64 },
}

impl OptSpec {
    /// Paper defaults: Adam(β1=.9, β2=.999), momentum β=.9.
    pub fn parse(name: &str, lr: f64) -> Option<OptSpec> {
        match name {
            "sgd" => Some(OptSpec::Sgd { lr }),
            "momentum" => Some(OptSpec::Momentum { lr, beta: 0.9, nesterov: false }),
            "nesterov" => Some(OptSpec::Momentum { lr, beta: 0.9, nesterov: true }),
            "adam" => Some(OptSpec::Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }),
            "adagrad" => Some(OptSpec::AdaGrad { lr, eps: 1e-10 }),
            "adabelief" => {
                Some(OptSpec::AdaBelief { lr, beta1: 0.9, beta2: 0.999, eps: 1e-12 })
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptSpec::Sgd { .. } => "sgd",
            OptSpec::Momentum { nesterov: false, .. } => "momentum",
            OptSpec::Momentum { nesterov: true, .. } => "nesterov",
            OptSpec::Adam { .. } => "adam",
            OptSpec::AdaGrad { .. } => "adagrad",
            OptSpec::AdaBelief { .. } => "adabelief",
        }
    }

    pub fn lr(&self) -> f64 {
        match self {
            OptSpec::Sgd { lr }
            | OptSpec::Momentum { lr, .. }
            | OptSpec::Adam { lr, .. }
            | OptSpec::AdaGrad { lr, .. }
            | OptSpec::AdaBelief { lr, .. } => *lr,
        }
    }

    /// Instantiate for a parameter vector of size `d`.
    pub fn build(&self, d: usize) -> Box<dyn Optimizer> {
        match *self {
            OptSpec::Sgd { lr } => Box::new(Sgd::new(lr)),
            OptSpec::Momentum { lr, beta, nesterov } => {
                Box::new(Momentum::new(lr, beta, nesterov, d))
            }
            OptSpec::Adam { lr, beta1, beta2, eps } => {
                Box::new(Adam::new(lr, beta1, beta2, eps, d))
            }
            OptSpec::AdaGrad { lr, eps } => Box::new(AdaGrad::new(lr, eps, d)),
            OptSpec::AdaBelief { lr, beta1, beta2, eps } => {
                Box::new(AdaBelief::new(lr, beta1, beta2, eps, d))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimizing f(x) = ||x||²/2 (grad = x) must converge for every
    /// optimizer from the same start.
    #[test]
    fn all_optimizers_descend_quadratic() {
        for name in ["sgd", "momentum", "nesterov", "adam", "adagrad", "adabelief"] {
            let spec = OptSpec::parse(name, 0.05).unwrap();
            let mut opt = spec.build(4);
            let mut x = vec![2.0f32, -1.5, 0.5, 3.0];
            let f0: f32 = x.iter().map(|v| v * v).sum();
            for _ in 0..500 {
                let g = x.clone();
                opt.step(&mut x, &g);
            }
            let f1: f32 = x.iter().map(|v| v * v).sum();
            // AdaGrad's effective lr decays ~1/sqrt(t), so hold every
            // optimizer to >= 5x reduction rather than a uniform tight bar.
            assert!(f1 < f0 * 0.2, "{name}: {f0} -> {f1}");
        }
    }

    #[test]
    fn clone_box_snapshots_state() {
        // Stateful optimizer: stepping the clone must not affect the
        // original (the proxy-chain requirement).
        let mut a = OptSpec::parse("adam", 0.1).unwrap().build(2);
        let mut x = vec![1.0f32, 1.0];
        a.step(&mut x, &[1.0, 1.0]);
        let mut b = a.clone_box();
        let mut xa = x.clone();
        let mut xb = x.clone();
        b.step(&mut xb, &[1.0, 1.0]);
        b.step(&mut xb, &[1.0, 1.0]);
        a.step(&mut xa, &[1.0, 1.0]);
        // The first post-snapshot step from identical states is identical.
        let mut c = a.clone_box();
        let mut xc = x.clone();
        c.step(&mut xc, &[1.0, 1.0]);
        assert_eq!(xa, xc);
        assert_ne!(xa, xb);
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(OptSpec::parse("lbfgs", 0.1).is_none());
    }

    #[test]
    fn set_lr_roundtrip() {
        for name in ["sgd", "momentum", "adam", "adagrad", "adabelief"] {
            let mut opt = OptSpec::parse(name, 0.1).unwrap().build(3);
            assert!((opt.lr() - 0.1).abs() < 1e-12);
            opt.set_lr(0.01);
            assert!((opt.lr() - 0.01).abs() < 1e-12, "{name}");
        }
    }
}
