//! AdaGrad (Duchi et al., 2011).

use super::Optimizer;

/// G ← G + g²;  θ ← θ − η g / (√G + ε).
#[derive(Clone, Debug)]
pub struct AdaGrad {
    lr: f64,
    eps: f64,
    g2: Vec<f32>,
}

impl AdaGrad {
    pub fn new(lr: f64, eps: f64, d: usize) -> Self {
        AdaGrad { lr, eps, g2: vec![0.0; d] }
    }
}

impl Optimizer for AdaGrad {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        let lr = self.lr as f32;
        let eps = self.eps as f32;
        for ((p, a), &g) in params.iter_mut().zip(&mut self.g2).zip(grad) {
            *a += g * g;
            *p -= lr * g / (a.sqrt() + eps);
        }
    }

    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn save_state(&self) -> Vec<Vec<f32>> {
        vec![self.g2.clone()]
    }

    fn load_state(&mut self, state: &[Vec<f32>]) -> Result<(), String> {
        match state {
            [g2] if g2.len() == self.g2.len() => {
                self.g2.copy_from_slice(g2);
                Ok(())
            }
            _ => Err("adagrad: bad state shape".into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_lr() {
        let mut o = AdaGrad::new(0.5, 0.0, 2);
        let mut p = vec![0.0f32, 0.0];
        o.step(&mut p, &[4.0, -0.25]);
        assert!((p[0] + 0.5).abs() < 1e-6);
        assert!((p[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn effective_lr_shrinks() {
        let mut o = AdaGrad::new(0.5, 0.0, 1);
        let mut p = vec![0.0f32];
        o.step(&mut p, &[1.0]);
        let d1 = -p[0];
        let before = p[0];
        o.step(&mut p, &[1.0]);
        let d2 = before - p[0];
        assert!(d2 < d1, "step must shrink: {d1} then {d2}");
    }
}
