//! Heavy-ball and Nesterov momentum (paper Related Work: Nesterov 1983,
//! Liu et al. 2020).

use super::Optimizer;

/// v ← βv + g;  θ ← θ − η·(g + βv) (Nesterov) or θ ← θ − ηv (heavy-ball).
#[derive(Clone, Debug)]
pub struct Momentum {
    lr: f64,
    beta: f64,
    nesterov: bool,
    v: Vec<f32>,
}

impl Momentum {
    pub fn new(lr: f64, beta: f64, nesterov: bool, d: usize) -> Self {
        Momentum { lr, beta, nesterov, v: vec![0.0; d] }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(params.len(), self.v.len());
        let lr = self.lr as f32;
        let beta = self.beta as f32;
        if self.nesterov {
            for ((p, v), &g) in params.iter_mut().zip(&mut self.v).zip(grad) {
                *v = beta * *v + g;
                *p -= lr * (g + beta * *v);
            }
        } else {
            for ((p, v), &g) in params.iter_mut().zip(&mut self.v).zip(grad) {
                *v = beta * *v + g;
                *p -= lr * *v;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        if self.nesterov {
            "nesterov"
        } else {
            "momentum"
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn save_state(&self) -> Vec<Vec<f32>> {
        vec![self.v.clone()]
    }

    fn load_state(&mut self, state: &[Vec<f32>]) -> Result<(), String> {
        match state {
            [v] if v.len() == self.v.len() => {
                self.v.copy_from_slice(v);
                Ok(())
            }
            _ => Err("momentum: bad state shape".into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_equals_sgd() {
        let mut o = Momentum::new(0.1, 0.9, false, 2);
        let mut p = vec![1.0f32, 1.0];
        o.step(&mut p, &[1.0, 2.0]);
        assert!((p[0] - 0.9).abs() < 1e-6);
        assert!((p[1] - 0.8).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let mut o = Momentum::new(0.1, 0.5, false, 1);
        let mut p = vec![0.0f32];
        o.step(&mut p, &[1.0]); // v=1,   p=-0.1
        o.step(&mut p, &[1.0]); // v=1.5, p=-0.25
        assert!((p[0] + 0.25).abs() < 1e-6);
    }

    #[test]
    fn nesterov_beats_heavy_ball_on_quadratic() {
        // Both descend; Nesterov converges at least as fast on this ill-
        // conditioned quadratic — a sanity check of the lookahead term.
        let run = |nesterov: bool| {
            let mut o = Momentum::new(0.02, 0.9, nesterov, 2);
            let mut x = vec![5.0f32, 5.0];
            for _ in 0..200 {
                let g = [x[0] * 10.0, x[1] * 0.5];
                o.step(&mut x, &g);
            }
            (x[0] * x[0] * 10.0 + x[1] * x[1] * 0.5) as f64
        };
        let hb = run(false);
        let nag = run(true);
        assert!(nag <= hb * 1.5, "nag={nag} hb={hb}");
    }
}
