//! Adam (Kingma & Ba, 2014) and AdaBelief (Zhuang et al., 2020) — the
//! adaptive FO-OPTs used by the paper's synthetic/RL experiments.

use super::Optimizer;

/// Bias-corrected Adam.
#[derive(Clone, Debug)]
pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(lr: f64, beta1: f64, beta2: f64, eps: f64, d: usize) -> Self {
        Adam { lr, beta1, beta2, eps, t: 0, m: vec![0.0; d], v: vec![0.0; d] }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1 = self.beta1 as f32;
        let b2 = self.beta2 as f32;
        let bc1 = 1.0 - (self.beta1).powi(self.t as i32);
        let bc2 = 1.0 - (self.beta2).powi(self.t as i32);
        // fold the bias corrections into one scalar step size
        let alpha = (self.lr * bc2.sqrt() / bc1) as f32;
        let eps = self.eps as f32;
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            params[i] -= alpha * self.m[i] / (self.v[i].sqrt() + eps);
        }
    }

    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn save_state(&self) -> Vec<Vec<f32>> {
        vec![vec![self.t as f32], self.m.clone(), self.v.clone()]
    }

    fn load_state(&mut self, state: &[Vec<f32>]) -> Result<(), String> {
        match state {
            [t, m, v] if t.len() == 1 && m.len() == self.m.len() && v.len() == self.v.len() => {
                self.t = t[0] as u64;
                self.m.copy_from_slice(m);
                self.v.copy_from_slice(v);
                Ok(())
            }
            _ => Err("adam: bad state shape".into()),
        }
    }
}

/// AdaBelief: Adam with the second moment tracking (g − m)² — "adapting
/// stepsizes by the belief in observed gradients".
#[derive(Clone, Debug)]
pub struct AdaBelief {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u64,
    m: Vec<f32>,
    s: Vec<f32>,
}

impl AdaBelief {
    pub fn new(lr: f64, beta1: f64, beta2: f64, eps: f64, d: usize) -> Self {
        AdaBelief { lr, beta1, beta2, eps, t: 0, m: vec![0.0; d], s: vec![0.0; d] }
    }
}

impl Optimizer for AdaBelief {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        self.t += 1;
        let b1 = self.beta1 as f32;
        let b2 = self.beta2 as f32;
        let bc1 = 1.0 - (self.beta1).powi(self.t as i32);
        let bc2 = 1.0 - (self.beta2).powi(self.t as i32);
        let alpha = (self.lr * bc2.sqrt() / bc1) as f32;
        let eps = self.eps as f32;
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            let diff = g - self.m[i];
            self.s[i] = b2 * self.s[i] + (1.0 - b2) * diff * diff + eps;
            params[i] -= alpha * self.m[i] / (self.s[i].sqrt() + eps);
        }
    }

    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "adabelief"
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn save_state(&self) -> Vec<Vec<f32>> {
        vec![vec![self.t as f32], self.m.clone(), self.s.clone()]
    }

    fn load_state(&mut self, state: &[Vec<f32>]) -> Result<(), String> {
        match state {
            [t, m, sv] if t.len() == 1 && m.len() == self.m.len() && sv.len() == self.s.len() => {
                self.t = t[0] as u64;
                self.m.copy_from_slice(m);
                self.s.copy_from_slice(sv);
                Ok(())
            }
            _ => Err("adabelief: bad state shape".into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_first_step_is_signed_lr() {
        // With bias correction, the first Adam step is ≈ lr * sign(g).
        let mut o = Adam::new(0.1, 0.9, 0.999, 1e-8, 3);
        let mut p = vec![0.0f32; 3];
        o.step(&mut p, &[3.0, -0.5, 0.0]);
        assert!((p[0] + 0.1).abs() < 1e-4, "{}", p[0]);
        assert!((p[1] - 0.1).abs() < 1e-4, "{}", p[1]);
        assert_eq!(p[2], 0.0);
    }

    #[test]
    fn adam_scale_invariance() {
        // Adam's update direction is invariant to gradient scaling.
        let run = |scale: f32| {
            let mut o = Adam::new(0.01, 0.9, 0.999, 1e-12, 1);
            let mut p = vec![1.0f32];
            for _ in 0..50 {
                let g = [p[0] * scale];
                o.step(&mut p, &g);
            }
            p[0]
        };
        let a = run(1.0);
        let b = run(100.0);
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }

    #[test]
    fn adabelief_first_step_descends() {
        let mut o = AdaBelief::new(0.1, 0.9, 0.999, 1e-12, 2);
        let mut p = vec![1.0f32, -1.0];
        o.step(&mut p, &[1.0, -1.0]);
        assert!(p[0] < 1.0);
        assert!(p[1] > -1.0);
    }

    #[test]
    fn state_advances_with_t() {
        let mut o = Adam::new(0.1, 0.9, 0.999, 1e-8, 1);
        let mut p = vec![0.0f32];
        o.step(&mut p, &[1.0]);
        let p1 = p[0];
        o.step(&mut p, &[1.0]);
        assert!(p[0] < p1, "second step must keep moving");
    }
}
