//! Plain stochastic gradient descent (Robbins & Monro, 1951) — the FO-OPT
//! analyzed in the paper's Thm. 2/3.

use super::Optimizer;

/// θ ← θ − η g. Stateless apart from the learning rate.
#[derive(Clone, Debug)]
pub struct Sgd {
    lr: f64,
}

impl Sgd {
    pub fn new(lr: f64) -> Self {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        let lr = self.lr as f32;
        for (p, &g) in params.iter_mut().zip(grad) {
            *p -= lr * g;
        }
    }

    fn clone_box(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_update_rule() {
        let mut o = Sgd::new(0.5);
        let mut p = vec![1.0f32, -2.0];
        o.step(&mut p, &[2.0, 2.0]);
        assert_eq!(p, vec![0.0, -3.0]);
    }

    #[test]
    fn zero_grad_is_noop() {
        let mut o = Sgd::new(0.1);
        let mut p = vec![1.5f32; 4];
        o.step(&mut p, &[0.0; 4]);
        assert_eq!(p, vec![1.5f32; 4]);
    }
}
