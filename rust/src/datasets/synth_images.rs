//! Procedural class-conditional image datasets — the MNIST / Fashion-MNIST
//! / CIFAR-10 substitutes (DESIGN.md §Substitutions).
//!
//! Real datasets are unavailable offline, so each class is defined by a
//! smooth 2-D frequency prototype (a few random sinusoid components per
//! class) plus per-sample blob deformation and pixel noise, clipped to
//! [0, 1]. This produces a stochastic minibatch loss landscape with the
//! same input dimensionality, class count and difficulty *ordering*
//! (mnist-like < fashion-like < cifar-like via rising noise levels) — the
//! optimizer-ranking claims of the paper are about this landscape shape,
//! not about pixel provenance.

use crate::util::Rng;

/// Dataset flavor: controls geometry and noise (difficulty).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageKind {
    /// 28×28×1 = 784 dims, low noise.
    MnistLike,
    /// 28×28×1 = 784 dims, medium noise.
    FashionLike,
    /// 32×32×3 = 3072 dims, high noise.
    CifarLike,
}

impl ImageKind {
    pub fn parse(s: &str) -> Option<ImageKind> {
        match s {
            "mnist" => Some(ImageKind::MnistLike),
            "fmnist" | "fashion" => Some(ImageKind::FashionLike),
            "cifar" | "cifar10" => Some(ImageKind::CifarLike),
            _ => None,
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            ImageKind::MnistLike | ImageKind::FashionLike => 28 * 28,
            ImageKind::CifarLike => 32 * 32 * 3,
        }
    }

    pub fn side(&self) -> usize {
        match self {
            ImageKind::MnistLike | ImageKind::FashionLike => 28,
            ImageKind::CifarLike => 32,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            ImageKind::CifarLike => 3,
            _ => 1,
        }
    }

    fn pixel_noise(&self) -> f32 {
        match self {
            ImageKind::MnistLike => 0.08,
            ImageKind::FashionLike => 0.15,
            ImageKind::CifarLike => 0.25,
        }
    }

    fn blob_noise(&self) -> f32 {
        match self {
            ImageKind::MnistLike => 0.2,
            ImageKind::FashionLike => 0.35,
            ImageKind::CifarLike => 0.5,
        }
    }
}

pub const N_CLASSES: usize = 10;

/// An in-memory labelled image set.
#[derive(Clone, Debug)]
pub struct ImageDataset {
    pub kind: ImageKind,
    /// Row-major `n × dim` pixels in [0, 1].
    pub x: Vec<f32>,
    /// Labels in [0, n_classes).
    pub y: Vec<u8>,
    pub dim: usize,
    /// Number of label classes (10 for the paper datasets; reduced by
    /// `crop` for the tiny test-profile artifacts).
    pub n_classes: usize,
}

/// One class's generative prototype: k sinusoid components per channel.
struct Proto {
    comps: Vec<(f32, f32, f32, f32, f32)>, // (fx, fy, phase, amp, chan_mix)
}

impl Proto {
    fn sample(rng: &mut Rng) -> Proto {
        let k = 4 + rng.below(3);
        let comps = (0..k)
            .map(|_| {
                (
                    rng.range(0.5, 4.0) as f32,
                    rng.range(0.5, 4.0) as f32,
                    rng.range(0.0, std::f64::consts::TAU) as f32,
                    rng.range(0.3, 1.0) as f32,
                    rng.range(0.0, 1.0) as f32,
                )
            })
            .collect();
        Proto { comps }
    }

    fn pixel(&self, u: f32, v: f32, chan: usize) -> f32 {
        let mut s = 0.0f32;
        for &(fx, fy, ph, amp, mix) in &self.comps {
            let cw = 1.0 + 0.5 * mix * chan as f32;
            s += amp * (std::f32::consts::TAU * (fx * u * cw + fy * v) + ph).sin();
        }
        0.5 + 0.25 * s
    }
}

impl ImageDataset {
    /// Generate `n` samples, classes balanced round-robin. Deterministic
    /// in (kind, seed, n).
    pub fn generate(kind: ImageKind, n: usize, seed: u64) -> ImageDataset {
        let mut rng = Rng::new(seed ^ 0x1A6E_5EED);
        let protos: Vec<Proto> = (0..N_CLASSES).map(|_| Proto::sample(&mut rng)).collect();
        let side = kind.side();
        let chans = kind.channels();
        let dim = kind.dim();
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let cls = i % N_CLASSES;
            y.push(cls as u8);
            // per-sample smooth deformation: translation + scale jitter
            let dx = rng.normal() as f32 * 0.05 * kind.blob_noise();
            let dy = rng.normal() as f32 * 0.05 * kind.blob_noise();
            let sc = 1.0 + rng.normal() as f32 * 0.1 * kind.blob_noise();
            let pn = kind.pixel_noise();
            for c in 0..chans {
                for py in 0..side {
                    for px in 0..side {
                        let u = (px as f32 / side as f32) * sc + dx;
                        let v = (py as f32 / side as f32) * sc + dy;
                        let base = protos[cls].pixel(u, v, c);
                        let val = base + rng.normal() as f32 * pn;
                        x.push(val.clamp(0.0, 1.0));
                    }
                }
            }
        }
        ImageDataset { kind, x, y, dim, n_classes: N_CLASSES }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Sample a minibatch: pixels flattened `(batch × dim)` and one-hot
    /// labels `(batch × 10)` — exactly the MLP artifact input layout.
    pub fn sample_batch(
        &self,
        batch: usize,
        rng: &mut Rng,
        x_out: &mut Vec<f32>,
        y_out: &mut Vec<f32>,
    ) {
        x_out.clear();
        y_out.clear();
        x_out.reserve(batch * self.dim);
        y_out.resize(batch * self.n_classes, 0.0);
        y_out.iter_mut().for_each(|v| *v = 0.0);
        for b in 0..batch {
            let i = rng.below(self.len());
            x_out.extend_from_slice(self.image(i));
            y_out[b * self.n_classes + self.y[i] as usize] = 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        for kind in [ImageKind::MnistLike, ImageKind::FashionLike, ImageKind::CifarLike] {
            let ds = ImageDataset::generate(kind, 40, 0);
            assert_eq!(ds.len(), 40);
            assert_eq!(ds.x.len(), 40 * kind.dim());
            assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
            assert!(ds.y.iter().all(|&c| (c as usize) < N_CLASSES));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = ImageDataset::generate(ImageKind::MnistLike, 20, 7);
        let b = ImageDataset::generate(ImageKind::MnistLike, 20, 7);
        assert_eq!(a.x, b.x);
        let c = ImageDataset::generate(ImageKind::MnistLike, 20, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_are_balanced() {
        let ds = ImageDataset::generate(ImageKind::CifarLike, 100, 1);
        let mut counts = [0usize; N_CLASSES];
        for &c in &ds.y {
            counts[c as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-class-mean classification on clean data must beat chance
        // by a wide margin — otherwise the "dataset" carries no signal.
        let ds = ImageDataset::generate(ImageKind::MnistLike, 300, 3);
        let dim = ds.dim;
        let mut means = vec![vec![0.0f64; dim]; N_CLASSES];
        let mut counts = [0usize; N_CLASSES];
        // fit on the first 200
        for i in 0..200 {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(ds.image(i)) {
                *m += v as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            m.iter_mut().for_each(|v| *v /= c.max(1) as f64);
        }
        // score on the last 100
        let mut correct = 0;
        for i in 200..300 {
            let img = ds.image(i);
            let pred = (0..N_CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(img)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(img)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if pred == ds.y[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 50, "nearest-mean accuracy too low: {correct}/100");
    }

    #[test]
    fn batch_layout_one_hot() {
        let ds = ImageDataset::generate(ImageKind::MnistLike, 30, 0);
        let mut rng = Rng::new(0);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        ds.sample_batch(8, &mut rng, &mut x, &mut y);
        assert_eq!(x.len(), 8 * 784);
        assert_eq!(y.len(), 8 * 10);
        for b in 0..8 {
            let row = &y[b * 10..(b + 1) * 10];
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), 9);
        }
    }
}
