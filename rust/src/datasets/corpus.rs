//! Character-level text corpora for the autoregression workloads.
//!
//! * `shakespeare()` — a genuine public-domain excerpt (Sonnets I–VI),
//!   the "curated collection of works from Shakespeare" stand-in.
//! * `synthetic_narrative()` — a seeded template-grammar generator with
//!   English-like token statistics: the substitute for the copyrighted
//!   "Harry Potter and the Sorcerer's Stone" corpus (DESIGN.md
//!   §Substitutions). What matters for the char-LM loss-curve shapes is
//!   vocabulary size, word/sentence length distributions and n-gram
//!   predictability, all of which the grammar controls.
//!
//! Vocabulary: printable ASCII 0x20..0x7E plus '\n', mapped to ids
//! 0..=95 — exactly the `vocab = 96` of the transformer artifacts.

use crate::util::Rng;

/// Fixed char vocabulary shared with the tfm artifacts.
pub const VOCAB: usize = 96;

/// Map a char to its token id (unknown chars -> space).
pub fn char_to_id(c: char) -> i32 {
    match c {
        '\n' => 95,
        c if (' '..='~').contains(&c) => (c as u32 - ' ' as u32) as i32,
        _ => 0,
    }
}

/// Inverse of [`char_to_id`].
pub fn id_to_char(id: i32) -> char {
    match id {
        95 => '\n',
        i if (0..95).contains(&i) => char::from_u32(' ' as u32 + i as u32).unwrap(),
        _ => ' ',
    }
}

/// Tokenize a string.
pub fn encode(text: &str) -> Vec<i32> {
    text.chars().map(char_to_id).collect()
}

/// Public-domain Shakespeare excerpt (Sonnets I–VI, 1609 Quarto text).
pub fn shakespeare() -> &'static str {
    SONNETS
}

const SONNETS: &str = "\
From fairest creatures we desire increase,
That thereby beauty's rose might never die,
But as the riper should by time decease,
His tender heir might bear his memory:
But thou, contracted to thine own bright eyes,
Feed'st thy light's flame with self-substantial fuel,
Making a famine where abundance lies,
Thyself thy foe, to thy sweet self too cruel.
Thou that art now the world's fresh ornament
And only herald to the gaudy spring,
Within thine own bud buriest thy content
And, tender churl, mak'st waste in niggarding.
Pity the world, or else this glutton be,
To eat the world's due, by the grave and thee.

When forty winters shall besiege thy brow
And dig deep trenches in thy beauty's field,
Thy youth's proud livery, so gazed on now,
Will be a tattered weed of small worth held:
Then being asked where all thy beauty lies,
Where all the treasure of thy lusty days,
To say within thine own deep-sunken eyes
Were an all-eating shame and thriftless praise.
How much more praise deserved thy beauty's use
If thou couldst answer 'This fair child of mine
Shall sum my count and make my old excuse,'
Proving his beauty by succession thine.
This were to be new made when thou art old
And see thy blood warm when thou feel'st it cold.

Look in thy glass and tell the face thou viewest
Now is the time that face should form another,
Whose fresh repair if now thou not renewest,
Thou dost beguile the world, unbless some mother.
For where is she so fair whose uneared womb
Disdains the tillage of thy husbandry?
Or who is he so fond will be the tomb
Of his self-love, to stop posterity?
Thou art thy mother's glass, and she in thee
Calls back the lovely April of her prime;
So thou through windows of thine age shalt see,
Despite of wrinkles, this thy golden time.
But if thou live remembered not to be,
Die single, and thine image dies with thee.

Unthrifty loveliness, why dost thou spend
Upon thyself thy beauty's legacy?
Nature's bequest gives nothing, but doth lend,
And being frank she lends to those are free.
Then, beauteous niggard, why dost thou abuse
The bounteous largess given thee to give?
Profitless usurer, why dost thou use
So great a sum of sums yet canst not live?
For having traffic with thyself alone,
Thou of thyself thy sweet self dost deceive.
Then how, when Nature calls thee to be gone,
What acceptable audit canst thou leave?
Thy unused beauty must be tombed with thee,
Which used lives th' executor to be.

Those hours that with gentle work did frame
The lovely gaze where every eye doth dwell
Will play the tyrants to the very same
And that unfair which fairly doth excel;
For never-resting time leads summer on
To hideous winter and confounds him there,
Sap checked with frost and lusty leaves quite gone,
Beauty o'ersnowed and bareness everywhere.
Then were not summer's distillation left
A liquid prisoner pent in walls of glass,
Beauty's effect with beauty were bereft,
Nor it nor no remembrance what it was.
But flowers distilled, though they with winter meet,
Leese but their show; their substance still lives sweet.

Then let not winter's ragged hand deface
In thee thy summer ere thou be distilled:
Make sweet some vial; treasure thou some place
With beauty's treasure ere it be self-killed.
That use is not forbidden usury
Which happies those that pay the willing loan;
That's for thyself to breed another thee,
Or ten times happier, be it ten for one.
";

/// Seeded English-like narrative generator (the HP-corpus substitute).
pub fn synthetic_narrative(seed: u64, target_chars: usize) -> String {
    const NAMES: &[&str] = &[
        "Harlan", "Petra", "Ronan", "Hermia", "Albus", "Minerva", "Severin",
        "Ginevra", "Neville", "Luna",
    ];
    const PLACES: &[&str] = &[
        "the castle", "the great hall", "the forbidden wood", "the dungeons",
        "the tower", "the library", "the lake", "the village",
    ];
    const VERBS: &[&str] = &[
        "hurried toward", "whispered about", "stumbled into", "gazed at",
        "crept past", "studied", "discovered", "vanished behind", "guarded",
        "remembered",
    ];
    const OBJECTS: &[&str] = &[
        "a silver key", "the ancient map", "a flickering lantern",
        "the hidden door", "an old letter", "the broken wand",
        "a strange stone", "the locked chest", "a faded portrait",
    ];
    const CONNECTORS: &[&str] = &[
        "Meanwhile", "Later that night", "At dawn", "Without warning",
        "After the lesson", "Before supper", "In the silence",
    ];

    let mut rng = Rng::new(seed ^ 0xC0_4935);
    let mut out = String::with_capacity(target_chars + 64);
    while out.len() < target_chars {
        let style = rng.below(3);
        let s = match style {
            0 => format!(
                "{} {} {} near {}. ",
                NAMES[rng.below(NAMES.len())],
                VERBS[rng.below(VERBS.len())],
                OBJECTS[rng.below(OBJECTS.len())],
                PLACES[rng.below(PLACES.len())],
            ),
            1 => format!(
                "{}, {} and {} {} {}. ",
                CONNECTORS[rng.below(CONNECTORS.len())],
                NAMES[rng.below(NAMES.len())],
                NAMES[rng.below(NAMES.len())],
                VERBS[rng.below(VERBS.len())],
                OBJECTS[rng.below(OBJECTS.len())],
            ),
            _ => format!(
                "\"{}!\" said {}, and {} {}. ",
                OBJECTS[rng.below(OBJECTS.len())],
                NAMES[rng.below(NAMES.len())],
                NAMES[rng.below(NAMES.len())],
                VERBS[rng.below(VERBS.len())],
            ),
        };
        out.push_str(&s);
        if rng.coin(0.12) {
            out.push('\n');
        }
    }
    out
}

/// Tokenized corpus with minibatch sampling for the tfm artifacts.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub tokens: Vec<i32>,
}

impl Corpus {
    pub fn from_text(text: &str) -> Corpus {
        Corpus { tokens: encode(text) }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Sample `batch` windows of length `seq_plus_1`, flattened row-major —
    /// exactly the tfm artifact `tokens (B, L+1) i32` input.
    pub fn sample_windows(
        &self,
        batch: usize,
        seq_plus_1: usize,
        rng: &mut Rng,
        out: &mut Vec<i32>,
    ) {
        assert!(
            self.tokens.len() >= seq_plus_1,
            "corpus shorter than one window"
        );
        out.clear();
        out.reserve(batch * seq_plus_1);
        let span = self.tokens.len() - seq_plus_1 + 1;
        for _ in 0..batch {
            let start = rng.below(span);
            out.extend_from_slice(&self.tokens[start..start + seq_plus_1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_roundtrip() {
        for id in 0..VOCAB as i32 {
            assert_eq!(char_to_id(id_to_char(id)), id);
        }
        assert_eq!(char_to_id('\u{1F600}'), 0); // unknown -> space id
    }

    #[test]
    fn shakespeare_tokenizes_in_vocab() {
        let toks = encode(shakespeare());
        assert!(toks.len() > 3000, "excerpt too short: {}", toks.len());
        assert!(toks.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn narrative_is_deterministic_and_sized() {
        let a = synthetic_narrative(3, 5000);
        let b = synthetic_narrative(3, 5000);
        assert_eq!(a, b);
        assert!(a.len() >= 5000);
        assert_ne!(a, synthetic_narrative(4, 5000));
        // english-like: mostly letters+spaces, contains sentences
        assert!(a.contains(". "));
        let letters = a.chars().filter(|c| c.is_ascii_alphabetic()).count();
        assert!(letters as f64 > a.len() as f64 * 0.6);
    }

    #[test]
    fn windows_have_right_shape_and_content() {
        let c = Corpus::from_text(shakespeare());
        let mut rng = Rng::new(0);
        let mut out = Vec::new();
        c.sample_windows(4, 17, &mut rng, &mut out);
        assert_eq!(out.len(), 4 * 17);
        // each window is a contiguous slice of the corpus
        for w in 0..4 {
            let win = &out[w * 17..(w + 1) * 17];
            let hay = &c.tokens;
            assert!(
                hay.windows(17).any(|s| s == win),
                "window {w} is not contiguous corpus text"
            );
        }
    }

    #[test]
    #[should_panic(expected = "shorter")]
    fn window_longer_than_corpus_panics() {
        let c = Corpus::from_text("ab");
        let mut rng = Rng::new(0);
        let mut out = Vec::new();
        c.sample_windows(1, 10, &mut rng, &mut out);
    }
}
