//! Dataset substrates: procedural image sets and char corpora
//! (substitutes for MNIST/F-MNIST/CIFAR-10/Shakespeare/HP — see
//! DESIGN.md §Substitutions for the preservation argument).

pub mod corpus;
pub mod synth_images;

pub use corpus::{Corpus, VOCAB};
pub use synth_images::{ImageDataset, ImageKind, N_CLASSES};
