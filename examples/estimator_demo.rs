//! Kernelized gradient estimation in isolation (paper Sec. 4.1):
//! watch the posterior mean converge to the true gradient — and the
//! posterior variance collapse — as the local history grows along a real
//! optimization trajectory.
//!
//!     cargo run --release --example estimator_demo

use optex::gp::{estimator, GpConfig, Kernel};
use optex::opt::OptSpec;
use optex::util::stats;
use optex::util::Rng;
use optex::workloads::synthetic::SynthFn;

fn main() {
    let d = 2_000;
    let f = SynthFn::Rosenbrock;
    let mut rng = Rng::new(0);

    // Collect (θ_τ, ∇F(θ_τ)) along a Vanilla-Adam trajectory.
    let mut theta: Vec<f32> = (0..d).map(|_| 3.0 + 0.5 * rng.normal() as f32).collect();
    let mut opt = OptSpec::parse("adam", 0.1).unwrap().build(d);
    let n = 48;
    let mut thetas = Vec::new();
    let mut grads = Vec::new();
    let mut g = vec![0.0f32; d];
    for _ in 0..n {
        f.value_and_grad(&theta, &mut g);
        thetas.push(theta.clone());
        grads.push(g.clone());
        opt.step(&mut theta, &g);
    }

    // True gradient at the *next* iterate — the quantity the proxy
    // updates need (eq. (5)).
    let query = &theta;
    let mut true_grad = vec![0.0f32; d];
    f.value_and_grad(query, &mut true_grad);
    let true_norm = stats::norm2(&true_grad);

    println!("rosenbrock d={d}: predict grad at the next iterate from the last T0 steps\n");
    println!("  T0   kernel      rel. error   post. var");
    for kernel in [Kernel::Rbf, Kernel::Matern52] {
        for t0 in [2usize, 4, 8, 16, 32] {
            let lo = n - t0;
            let hist: Vec<&[f32]> = thetas[lo..].iter().map(|v| v.as_slice()).collect();
            let gh: Vec<&[f32]> = grads[lo..].iter().map(|v| v.as_slice()).collect();
            let cfg = GpConfig { kernel, lengthscale: None, sigma2: 1e-4, ..GpConfig::default() };
            let mut mu = vec![0.0f32; d];
            let est = estimator::estimate(&cfg, query, &hist, &gh, &mut mu);
            let err: f64 = mu
                .iter()
                .zip(&true_grad)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
                / true_norm;
            println!(
                "  {t0:<4} {:<10} {err:>10.4}   {:>9.2e}",
                kernel.name(),
                est.var
            );
        }
        println!();
    }
    println!("error and variance both fall as T0 grows (Thm. 1 / Cor. 1).");
}
