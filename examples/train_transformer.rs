//! END-TO-END driver (DESIGN.md §5.3): train the char-level transformer
//! on the Shakespeare corpus through the FULL three-layer stack —
//!
//!   L1 Pallas GP kernels + L2 JAX transformer  →  AOT HLO artifacts
//!   →  L3 rust coordinator (this binary): OptEx proxy chain + N-worker
//!      PJRT pool, SGD lr = 0.01 (paper Appx B.2.3), N = 4, T₀ = 10.
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example train_transformer [-- STEPS]
//!
//! Trains OptEx vs Vanilla for a few hundred sequential iterations and
//! prints the loss curves; the run recorded in EXPERIMENTS.md §End-to-end
//! used the default 300 steps.

use optex::config::{Backend, Method, RunConfig};
use optex::coordinator::optex::run;
use optex::opt::OptSpec;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut cfg = RunConfig::default();
    cfg.workload = "shakespeare".into();
    cfg.steps = steps;
    cfg.seed = 0;
    cfg.log_every = 1;
    cfg.optimizer = OptSpec::Sgd { lr: 0.01 };
    cfg.optex.parallelism = 4;
    cfg.optex.t0 = 10;
    cfg.optex.sigma2 = 0.01;
    // Use the gp_tfm HLO artifact for estimation too: the whole request
    // path (model fwd/bwd AND the GP posterior) runs through PJRT.
    cfg.optex.backend = Backend::Hlo;

    println!("char transformer on Shakespeare — full three-layer stack");
    println!("steps={steps}, N=4, T0=10, SGD lr=0.01 (paper Appx B.2.3)\n");

    let mut curves = Vec::new();
    for method in [Method::Vanilla, Method::Optex] {
        let mut c = cfg.clone();
        c.method = method;
        if method == Method::Vanilla {
            c.optex.backend = Backend::Native; // N=1: no estimation at all
        }
        let t0 = std::time::Instant::now();
        let rec = run(&c)?;
        println!(
            "{}  ({:.1}s measured)",
            rec.summary(),
            t0.elapsed().as_secs_f64()
        );
        let path = format!("results/e2e_transformer_{}.csv", method.name());
        rec.to_csv(std::path::Path::new(&path))?;
        println!("  wrote {path}");
        curves.push((method, rec));
    }

    // loss-curve table every ~10% of the run
    println!("\n  iter    vanilla      optex");
    let (v, o) = (&curves[0].1, &curves[1].1);
    let stride = (steps / 10).max(1);
    for i in (stride - 1..steps).step_by(stride) {
        let lv = v.rows.get(i).map(|r| r.loss).unwrap_or(f64::NAN);
        let lo = o.rows.get(i).map(|r| r.loss).unwrap_or(f64::NAN);
        println!("  {:>5}  {lv:>9.4}  {lo:>9.4}", i + 1);
    }
    let target = v.best_loss();
    if let Some(t) = o.iters_to_reach(target) {
        println!(
            "\nOptEx reached Vanilla's final loss ({target:.4}) in {t} of {steps} \
             sequential iterations ({:.2}x; Cor. 2 predicts ~2x at N=4)",
            steps as f64 / t as f64
        );
    }
    Ok(())
}
