//! DQN on CartPole with OptEx-accelerated q-network training (paper
//! Sec. 6.2 in miniature): the replay buffer, ε-greedy exploration and
//! environment are the rust substrates; the TD-gradient oracle is the
//! OptEx parallel phase.
//!
//!     cargo run --release --example rl_cartpole [-- EPISODES]

use optex::config::{Method, RunConfig};
use optex::opt::OptSpec;
use optex::rl::dqn::{train, RlConfig};

fn main() -> anyhow::Result<()> {
    let episodes: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);

    let mut rl = RlConfig::paper("cartpole");
    rl.episodes = episodes;
    rl.warmup_episodes = (episodes / 8).max(2);
    rl.batch = 128;

    println!("DQN CartPole — {episodes} episodes, N=4, T0=150, Adam lr=1e-3\n");
    for method in [Method::Vanilla, Method::Optex] {
        let mut cfg = RunConfig::default();
        cfg.workload = "cartpole".into();
        cfg.method = method;
        cfg.seed = 1;
        cfg.optimizer = OptSpec::Adam { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
        cfg.optex.parallelism = 4;
        cfg.optex.t0 = 150;
        cfg.optex.sigma2 = 0.01;
        let rec = train(&cfg, &rl)?;
        let aux = rec.aux_series();
        println!(
            "{:8}  cumulative avg reward: start={:.1} mid={:.1} final={:.1}",
            method.name(),
            aux.first().unwrap_or(&f64::NAN),
            aux.get(aux.len() / 2).unwrap_or(&f64::NAN),
            aux.last().unwrap_or(&f64::NAN),
        );
        rec.to_csv(std::path::Path::new(&format!(
            "results/e2e_cartpole_{}.csv",
            method.name()
        )))?;
    }
    println!("\nCSV series written under results/ (Fig-3 protocol: `optex fig 3`)");
    Ok(())
}
