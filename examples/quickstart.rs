//! Quickstart: OptEx vs Vanilla on the (deterministic) Rosenbrock
//! function — no AOT artifacts needed, runs in seconds.
//!
//!     cargo run --release --example quickstart
//!
//! This is Algorithm 1 end-to-end on the native backend: kernelized
//! gradient estimation over the local history, N−1 proxy updates, N
//! "parallel" ground-truth steps per sequential iteration. Expect OptEx
//! to reach Vanilla's final optimality gap in roughly √N-fewer sequential
//! iterations (paper Cor. 2).

use optex::config::{Method, RunConfig};
use optex::coordinator::optex::run;
use optex::gp::Kernel;
use optex::opt::OptSpec;

fn main() -> anyhow::Result<()> {
    let n = 5;
    let steps = 120;

    let mut cfg = RunConfig::default();
    cfg.workload = "rosenbrock".into();
    cfg.steps = steps;
    cfg.synth_dim = 5_000;
    cfg.seed = 0;
    cfg.optimizer = OptSpec::Adam { lr: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
    cfg.optex.parallelism = n;
    cfg.optex.t0 = 20;
    cfg.optex.kernel = Kernel::Matern52;

    println!("Rosenbrock, d={}, Adam lr=0.1, N={n}, T0=20\n", cfg.synth_dim);
    let mut results = Vec::new();
    for method in [Method::Vanilla, Method::Target, Method::Optex] {
        let mut c = cfg.clone();
        c.method = method;
        let rec = run(&c)?;
        println!("{}", rec.summary());
        results.push((method, rec));
    }

    let vanilla_final = results[0].1.best_loss();
    println!("\nsequential iterations to reach Vanilla's final gap ({vanilla_final:.3e}):");
    for (method, rec) in &results {
        match rec.iters_to_reach(vanilla_final) {
            Some(t) => println!(
                "  {:8} {t:>4} iters  ({:.2}x speedup)",
                method.name(),
                steps as f64 / t as f64
            ),
            None => println!("  {:8} not reached", method.name()),
        }
    }
    println!("\npaper Cor. 2 predicts Θ(√N) = {:.2}x for OptEx", (n as f64).sqrt());
    Ok(())
}
