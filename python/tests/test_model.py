"""Layer-2 model graphs: shapes, finite-difference gradient checks, and
workload-specific semantics."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _fd_check(fn, x, g, eps=1e-3, n_dirs=4, rtol=0.12, seed=0):
    """Directional finite differences against the returned gradient."""
    r = np.random.default_rng(seed)
    x = np.asarray(x, np.float64)
    g = np.asarray(g, np.float64)
    for _ in range(n_dirs):
        v = r.normal(size=x.shape)
        v /= np.linalg.norm(v)
        fp = float(fn(jnp.asarray((x + eps * v).astype(np.float32))))
        fm = float(fn(jnp.asarray((x - eps * v).astype(np.float32))))
        fd = (fp - fm) / (2 * eps)
        an = float(g @ v)
        assert an == pytest.approx(fd, rel=rtol, abs=5e-3), (an, fd)


# -- synthetic ---------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(model.SYNTH_FNS))
def test_synth_grad_matches_fd(name):
    vag = model.synth_value_and_grad(name)
    theta = np.random.default_rng(1).normal(size=20).astype(np.float32)
    f, g = vag(jnp.asarray(theta))
    assert np.isfinite(float(f))
    _fd_check(model.SYNTH_FNS[name], theta, g)


def test_synth_minima():
    # Ackley & Sphere minimize at 0, Rosenbrock at 1 (paper B.2.1).
    z = jnp.zeros(10)
    o = jnp.ones(10)
    assert float(model.sphere(z)) == pytest.approx(0.0, abs=1e-3)
    assert float(model.ackley(z)) == pytest.approx(0.0, abs=1e-3)
    assert float(model.rosenbrock(o)) == pytest.approx(0.0, abs=1e-6)
    assert float(model.rosenbrock(z)) > 0


# -- MLP ---------------------------------------------------------------------


def test_mlp_dim_formula():
    cfg = model.MlpConfig(784, 320, 10, 9)
    want = 784 * 320 + 320 + 7 * (320 * 320 + 320) + 320 * 10 + 10
    assert cfg.dim == want


def test_mlp_paper_dims_close():
    # paper: d=978186 (MNIST 9-layer), d=2412298 (CIFAR 10-layer)
    mnist = model.MlpConfig(784, 320, 10, 9).dim
    cifar = model.MlpConfig(3072, 390, 10, 10).dim
    assert abs(mnist - 978186) / 978186 < 0.01
    assert abs(cifar - 2412298) / 2412298 < 0.01


def test_mlp_loss_grad_shapes_and_fd():
    cfg = model.MlpConfig(6, 5, 3, 4)
    vag = model.mlp_loss_grad_fn(cfg)
    r = np.random.default_rng(0)
    flat = (0.3 * r.normal(size=cfg.dim)).astype(np.float32)
    x = r.normal(size=(7, 6)).astype(np.float32)
    y = np.eye(3, dtype=np.float32)[r.integers(0, 3, size=7)]
    loss, grad, acc = vag(jnp.asarray(flat), jnp.asarray(x), jnp.asarray(y))
    assert grad.shape == (cfg.dim,)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0

    def f(fl):
        lo, _, _ = vag(fl, jnp.asarray(x), jnp.asarray(y))
        return lo

    _fd_check(f, flat, grad, eps=1e-2, n_dirs=3)


def test_mlp_perfect_prediction_low_loss():
    cfg = model.MlpConfig(4, 8, 2, 3)
    vag = model.mlp_loss_grad_fn(cfg)
    # labels determined by a linear rule the net can fit after a few steps
    r = np.random.default_rng(2)
    flat = (0.5 * r.normal(size=cfg.dim)).astype(np.float32)
    x = r.normal(size=(32, 4)).astype(np.float32)
    y = np.eye(2, dtype=np.float32)[(x[:, 0] > 0).astype(int)]
    th = jnp.asarray(flat)
    for _ in range(300):
        _, g, _ = vag(th, jnp.asarray(x), jnp.asarray(y))
        th = th - 0.1 * g
    loss, _, acc = vag(th, jnp.asarray(x), jnp.asarray(y))
    assert float(acc) > 0.9
    assert float(loss) < 0.4


# -- transformer ---------------------------------------------------------------


def test_tfm_dim_and_shapes():
    cfg = model.TfmConfig(vocab=32, seq=16, embed=32, heads=2, blocks=1)
    assert cfg.dim == model.shapes_size(cfg.shapes)
    vag = model.tfm_loss_grad_fn(cfg)
    r = np.random.default_rng(0)
    flat = (0.05 * r.normal(size=cfg.dim)).astype(np.float32)
    toks = r.integers(0, 32, size=(3, 17)).astype(np.int32)
    loss, grad = vag(jnp.asarray(flat), jnp.asarray(toks))
    assert grad.shape == (cfg.dim,)
    # random init, uniform-ish predictions: loss ~ ln(vocab)
    assert abs(float(loss) - math.log(32)) < 1.0


def test_tfm_causality():
    """Changing a future token must not change earlier-position logits."""
    cfg = model.TfmConfig(vocab=16, seq=8, embed=16, heads=2, blocks=1)
    r = np.random.default_rng(1)
    flat = jnp.asarray((0.1 * r.normal(size=cfg.dim)).astype(np.float32))
    toks = r.integers(0, 16, size=(1, 8)).astype(np.int32)
    la = model.tfm_logits(cfg, flat, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 3) % 16
    lb = model.tfm_logits(cfg, flat, jnp.asarray(toks2))
    np.testing.assert_allclose(
        np.asarray(la)[0, :-1], np.asarray(lb)[0, :-1], rtol=1e-4, atol=1e-5
    )
    assert not np.allclose(np.asarray(la)[0, -1], np.asarray(lb)[0, -1])


def test_tfm_paper_dim_close():
    # paper transformer d=1626496
    cfg = model.TfmConfig(vocab=96, seq=128, embed=192, heads=4, blocks=4)
    assert abs(cfg.dim - 1626496) / 1626496 < 0.25


def test_tfm_grad_fd():
    cfg = model.TfmConfig(vocab=12, seq=6, embed=8, heads=2, blocks=1)
    vag = model.tfm_loss_grad_fn(cfg)
    r = np.random.default_rng(4)
    flat = (0.2 * r.normal(size=cfg.dim)).astype(np.float32)
    toks = jnp.asarray(r.integers(0, 12, size=(2, 7)).astype(np.int32))
    _, grad = vag(jnp.asarray(flat), toks)

    def f(fl):
        lo, _ = vag(fl, toks)
        return lo

    _fd_check(f, flat, grad, eps=1e-2, n_dirs=3, rtol=0.15)


# -- qnet ---------------------------------------------------------------------


def test_qnet_shapes_and_td_zero_loss():
    cfg = model.QNetConfig(4, 2, 8)
    train = model.qnet_train_fn(cfg, gamma=0.0)
    r = np.random.default_rng(0)
    flat = (0.3 * r.normal(size=cfg.dim)).astype(np.float32)
    obs = r.normal(size=(16, 4)).astype(np.float32)
    act = r.integers(0, 2, size=16).astype(np.int32)
    next_obs = r.normal(size=(16, 4)).astype(np.float32)
    done = np.ones(16, np.float32)
    q = np.asarray(model.qnet_forward(cfg, jnp.asarray(flat), jnp.asarray(obs)))
    rew = q[np.arange(16), act].astype(np.float32)
    # gamma=0, done=1 and rew == q(s,a): TD error is exactly zero
    loss, grad = train(
        jnp.asarray(flat), jnp.asarray(flat), jnp.asarray(obs), jnp.asarray(act),
        jnp.asarray(rew), jnp.asarray(next_obs), jnp.asarray(done),
    )
    assert float(loss) == pytest.approx(0.0, abs=1e-8)
    np.testing.assert_allclose(np.asarray(grad), 0.0, atol=1e-6)


def test_qnet_grad_only_through_online_net():
    cfg = model.QNetConfig(3, 2, 6)
    train = model.qnet_train_fn(cfg)
    r = np.random.default_rng(1)
    flat = jnp.asarray((0.3 * r.normal(size=cfg.dim)).astype(np.float32))
    tgt = jnp.asarray((0.3 * r.normal(size=cfg.dim)).astype(np.float32))
    obs = jnp.asarray(r.normal(size=(8, 3)).astype(np.float32))
    act = jnp.asarray(r.integers(0, 2, size=8).astype(np.int32))
    rew = jnp.asarray(r.normal(size=8).astype(np.float32))
    nxt = jnp.asarray(r.normal(size=(8, 3)).astype(np.float32))
    done = jnp.asarray(np.zeros(8, np.float32))
    loss, grad = train(flat, tgt, obs, act, rew, nxt, done)
    assert float(loss) > 0
    assert float(jnp.linalg.norm(grad)) > 0


# -- plumbing ------------------------------------------------------------------


def test_unflatten_roundtrip():
    shapes = [(3, 4), (4,), (4, 2), (2,)]
    flat = jnp.arange(model.shapes_size(shapes), dtype=jnp.float32)
    parts = model.unflatten(flat, shapes)
    assert [p.shape for p in parts] == shapes
    back = jnp.concatenate([p.ravel() for p in parts])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))
