"""AOT emission smoke tests: HLO text well-formedness + manifest schema.

The numerics of the emitted artifacts are validated on the rust side
(rust/tests/hlo_roundtrip.rs) where the actual consumer runs them.
"""

import json

import pytest

from compile import aot, model


def test_profiles_enumerate():
    for profile in ("test", "default", "paper"):
        arts = aot.profile_artifacts(profile)
        names = [a.name for a in arts]
        assert len(names) == len(set(names)), "duplicate artifact names"
        assert any(a.meta["family"] == "gp_estimate" for a in arts)
        assert any(a.meta["family"] == "synth" for a in arts)


def test_gp_artifact_lowering_is_custom_call_free(tmp_path):
    art = next(a for a in aot.profile_artifacts("test") if a.name == "gp_test")
    text = aot.to_hlo_text(art.lower())
    assert text.startswith("HloModule")
    assert "custom-call" not in text, "lapack/ffi custom-call leaked into HLO"
    assert "f32[64]" in text  # output mu shape


def test_synth_artifact_lowering(tmp_path):
    art = next(a for a in aot.profile_artifacts("test") if "rosenbrock" in a.name)
    text = aot.to_hlo_text(art.lower())
    assert text.startswith("HloModule")
    assert "custom-call" not in text


def test_emit_writes_manifest(tmp_path):
    rc = aot.main(
        ["--out-dir", str(tmp_path), "--profile", "test", "--only", "synth_sphere"]
    )
    assert rc == 0
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["profile"] == "test"
    (entry,) = manifest["artifacts"]
    assert entry["name"] == "synth_sphere_d64"
    assert (tmp_path / entry["file"]).exists()
    assert entry["inputs"] == [{"shape": [64], "dtype": "f32"}]
    assert entry["meta"]["family"] == "synth"


def test_emit_caches(tmp_path):
    args = ["--out-dir", str(tmp_path), "--profile", "test", "--only", "qnet_test_act"]
    aot.main(args)
    first = (tmp_path / "qnet_test_act.hlo.txt").stat().st_mtime_ns
    aot.main(args)  # second run must not rewrite
    assert (tmp_path / "qnet_test_act.hlo.txt").stat().st_mtime_ns == first


def test_qnet_env_dims_match_design():
    # These dims are the contract with rust/src/rl/*.rs — breaking them
    # breaks artifact shapes silently, so pin them here.
    assert aot.QNET_ENVS["cartpole"].obs_dim == 4
    assert aot.QNET_ENVS["cartpole"].n_actions == 2
    assert aot.QNET_ENVS["acrobot"].obs_dim == 6
    assert aot.QNET_ENVS["acrobot"].n_actions == 3
    assert aot.QNET_ENVS["mountaincar"].obs_dim == 2
    assert aot.QNET_ENVS["mountaincar"].n_actions == 3
