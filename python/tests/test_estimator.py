"""Properties of the kernelized gradient estimator (paper Sec. 4.1 / 5.1).

These check the *mathematical* behaviour the theory promises, on the same
graph that gets lowered into the gp_estimate artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _setup(t, d, seed, ds=None):
    ds = ds or d
    r = np.random.default_rng(seed)
    hist = r.normal(size=(t, d)).astype(np.float32)
    grads = r.normal(size=(t, d)).astype(np.float32)
    return hist[:, :ds], grads


@pytest.mark.parametrize("kind", ref.KERNEL_KINDS)
def test_interpolation_at_history_points(kind):
    """With sigma^2 -> 0, the posterior mean interpolates observed grads
    (GP regression exactness) — the basis of the Thm-1 lower bound."""
    hist, grads = _setup(5, 48, 0)
    est = model.gp_estimate_fn(kind)
    for i in range(5):
        mu, var = est(
            jnp.asarray(hist[i]), jnp.asarray(hist), jnp.asarray(grads),
            jnp.float32(3.0), jnp.float32(0.0),
        )
        np.testing.assert_allclose(np.asarray(mu), grads[i], rtol=2e-2, atol=2e-2)
        assert float(var[0]) < 1e-2


def test_variance_nonincreasing_in_history(seed=7):
    """Lemma A.4: posterior variance norm is non-increasing in n."""
    r = np.random.default_rng(seed)
    d = 32
    theta = r.normal(size=d).astype(np.float32)
    pts = r.normal(size=(8, d)).astype(np.float32)
    last = np.inf
    for n in range(1, 9):
        hist = jnp.asarray(pts[:n])
        _, kvec = ref.gp_weights(jnp.asarray(theta), hist, 2.0, 0.1)
        w, _ = ref.gp_weights(jnp.asarray(theta), hist, 2.0, 0.1)
        var = float(1.0 - jnp.dot(kvec, w))
        assert var <= last + 1e-5
        last = var


def test_variance_positive_and_bounded():
    hist, grads = _setup(6, 40, 3)
    est = model.gp_estimate_fn("matern52")
    theta = np.random.default_rng(9).normal(size=40).astype(np.float32) * 10
    mu, var = est(
        jnp.asarray(theta), jnp.asarray(hist), jnp.asarray(grads),
        jnp.float32(1.0), jnp.float32(0.05),
    )
    v = float(var[0])
    assert 0.0 <= v <= 1.0 + 1e-5  # unit-amplitude kernel: kappa = 1


def test_far_query_reverts_to_prior():
    """A query far outside the history support has mu ~ 0 (prior mean) and
    var ~ kappa — the estimator knows what it does not know."""
    hist, grads = _setup(5, 24, 1)
    est = model.gp_estimate_fn("rbf")
    theta = np.full(24, 100.0, np.float32)
    mu, var = est(
        jnp.asarray(theta), jnp.asarray(hist), jnp.asarray(grads),
        jnp.float32(1.0), jnp.float32(0.01),
    )
    assert float(jnp.max(jnp.abs(mu))) < 1e-3
    assert float(var[0]) > 0.99


@given(st.integers(2, 7), st.integers(8, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_estimate_matches_dense_posterior(t, d, seed):
    """The subset/pallas-composed graph equals the dense closed form when
    the subset is the full dimension set."""
    hist, grads = _setup(t, d, seed)
    est = model.gp_estimate_fn("matern52")
    theta = np.random.default_rng(seed + 1).normal(size=d).astype(np.float32)
    mu, var = est(
        jnp.asarray(theta), jnp.asarray(hist), jnp.asarray(grads),
        jnp.float32(2.0), jnp.float32(0.1),
    )
    mu_ref, var_ref = ref.gp_estimate(
        jnp.asarray(theta), jnp.asarray(hist), jnp.asarray(grads), 2.0, 0.1 + 1e-6
    )
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref), rtol=5e-3, atol=5e-3)
    assert float(var[0]) == pytest.approx(float(var_ref), abs=5e-3)


def test_estimation_error_decays_with_history():
    """Cor. 1 shape check: average error vs T0 decays for a smooth target
    gradient field sampled near a point (local-history regime)."""
    r = np.random.default_rng(5)
    d = 8
    a = (0.3 * r.normal(size=(d, d))).astype(np.float32)

    def true_grad(x):
        return x @ a.T  # smooth (linear) vector field

    center = r.normal(size=d).astype(np.float32)
    pts = center + 0.5 * r.normal(size=(24, d)).astype(np.float32)
    grads = np.stack([true_grad(p) for p in pts]).astype(np.float32)
    query = center + 0.2 * r.normal(size=d).astype(np.float32)
    est = model.gp_estimate_fn("rbf")
    errs = []
    for t0 in (2, 12, 24):
        mu, _ = est(
            jnp.asarray(query), jnp.asarray(pts[:t0]), jnp.asarray(grads[:t0]),
            jnp.float32(2.0), jnp.float32(1e-4),
        )
        errs.append(float(np.linalg.norm(np.asarray(mu) - true_grad(query))))
    assert min(errs[1:]) < errs[0] * 0.5, f"error did not decay: {errs}"
