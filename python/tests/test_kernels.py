"""Pallas kernels vs pure-jnp oracle (the CORE L1 correctness signal).

Hypothesis sweeps shapes, block sizes and kernel kinds; every property is
an exact-math identity so tolerances are float32-roundoff only.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gp_kernels as gk
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


@st.composite
def vec_hist(draw, max_t=9, max_d=300):
    t = draw(st.integers(1, max_t))
    d = draw(st.integers(1, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    r = _rng(seed)
    theta = r.normal(size=d).astype(np.float32)
    hist = r.normal(size=(t, d)).astype(np.float32)
    return theta, hist


@given(vec_hist(), st.sampled_from([7, 64, 128, 512]))
@settings(**SETTINGS)
def test_sqdist_vector_matches_ref(th, block):
    theta, hist = th
    got = gk.sqdist_vector_pallas(jnp.asarray(theta), jnp.asarray(hist), block_d=block)
    want = ref.sqdist_vector(jnp.asarray(theta), jnp.asarray(hist))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)


@given(vec_hist(), st.sampled_from([7, 64, 512]))
@settings(**SETTINGS)
def test_sqdist_matrix_matches_ref(th, block):
    _, hist = th
    got = gk.sqdist_matrix_pallas(jnp.asarray(hist), block_d=block)
    want = ref.sqdist_matrix(jnp.asarray(hist))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)


@given(
    st.integers(1, 8),
    st.integers(1, 700),
    st.sampled_from([13, 128, 4096]),
    st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_weighted_combine_matches_ref(t, d, block, seed):
    r = _rng(seed)
    w = r.normal(size=t).astype(np.float32)
    g = r.normal(size=(t, d)).astype(np.float32)
    got = gk.weighted_combine_pallas(jnp.asarray(w), jnp.asarray(g), block_d=block)
    want = ref.weighted_combine(jnp.asarray(w), jnp.asarray(g))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)


def test_sqdist_vector_zero_distance():
    hist = np.ones((3, 40), np.float32)
    got = gk.sqdist_vector_pallas(jnp.ones(40), jnp.asarray(hist))
    np.testing.assert_allclose(got, np.zeros(3), atol=1e-6)


def test_sqdist_matrix_diagonal_zero():
    r = _rng(0)
    hist = r.normal(size=(6, 130)).astype(np.float32)
    got = np.asarray(gk.sqdist_matrix_pallas(jnp.asarray(hist)))
    np.testing.assert_allclose(np.diag(got), np.zeros(6), atol=1e-4)
    np.testing.assert_allclose(got, got.T, rtol=1e-5, atol=1e-5)


def test_combine_single_row_is_scale():
    r = _rng(3)
    g = r.normal(size=(1, 257)).astype(np.float32)
    got = gk.weighted_combine_pallas(jnp.asarray([2.5], dtype=jnp.float32), jnp.asarray(g))
    np.testing.assert_allclose(got, 2.5 * g[0], rtol=1e-5)


@pytest.mark.parametrize("kind", ref.KERNEL_KINDS)
def test_kernel_map_unit_at_zero(kind):
    v = ref.kernel_from_sqdist(jnp.asarray([0.0, 1.0, 9.0]), 1.3, kind)
    v = np.asarray(v)
    assert v[0] == pytest.approx(1.0, abs=1e-3)
    assert np.all(np.diff(v) < 0), "kernel must decay with distance"
    assert np.all(v > 0)


@pytest.mark.parametrize("kind", ref.KERNEL_KINDS)
def test_kernel_map_lengthscale_monotone(kind):
    # Larger lengthscale => larger kernel value at the same distance.
    lo = float(ref.kernel_from_sqdist(jnp.asarray(4.0), 0.5, kind))
    hi = float(ref.kernel_from_sqdist(jnp.asarray(4.0), 5.0, kind))
    assert hi > lo
