"""Custom-call-free Cholesky solve vs numpy (L2 substrate)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import linalg


def _spd(n, seed, jitter=0.5):
    r = np.random.default_rng(seed)
    m = r.normal(size=(n, n)).astype(np.float32)
    return m @ m.T + jitter * np.eye(n, dtype=np.float32)


@given(st.integers(1, 24), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_chol_solve_matches_numpy(n, seed):
    a = _spd(n, seed)
    b = np.random.default_rng(seed + 1).normal(size=n).astype(np.float32)
    x = np.asarray(linalg.chol_solve(jnp.asarray(a), jnp.asarray(b)))
    want = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(x, want, rtol=2e-3, atol=2e-3)


@given(st.integers(1, 24), st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_cholesky_factor_reconstructs(n, seed):
    a = _spd(n, seed)
    l = np.asarray(linalg.cholesky(jnp.asarray(a)))
    assert np.allclose(np.triu(l, 1), 0.0), "L must be lower-triangular"
    np.testing.assert_allclose(l @ l.T, a, rtol=2e-3, atol=2e-3)


def test_solve_identity():
    b = jnp.asarray(np.arange(5, dtype=np.float32))
    x = linalg.chol_solve(jnp.eye(5), b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(b), rtol=1e-6)


def test_triangular_solves_roundtrip():
    a = _spd(7, 42)
    l = linalg.cholesky(jnp.asarray(a))
    b = jnp.asarray(np.random.default_rng(0).normal(size=7).astype(np.float32))
    y = linalg.solve_lower(l, b)
    np.testing.assert_allclose(np.asarray(l) @ np.asarray(y), np.asarray(b), rtol=1e-3, atol=1e-4)
    x = linalg.solve_upper_t(l, y)
    np.testing.assert_allclose(np.asarray(l).T @ np.asarray(x), np.asarray(y), rtol=1e-3, atol=1e-4)
