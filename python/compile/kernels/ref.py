"""Pure-jnp reference oracle for the OptEx GP kernels.

This module is the CORRECTNESS ground truth for the Pallas kernels in
``gp_kernels.py`` and, transitively, for the rust-native estimator in
``rust/src/gp/`` (which is cross-checked against HLO artifacts built from
these functions). Everything here is deliberately written in the most
obvious possible jnp, with no tiling or padding tricks.

Math (paper Prop. 4.1, separable kernel K(.,.) = k(.,.) I):

    mu_t(theta)    = [ k_t(theta)^T (K_t + sigma^2 I)^{-1} G_t ]^T
    Sigma_t^2      = ( k(theta,theta) - k_t(theta)^T (K_t+sigma^2 I)^{-1} k_t(theta) ) I

with k_t(theta) the kernel vector against the local history and K_t the
history Gram matrix. All kernels are unit-amplitude (kappa = k(x,x) = 1).
"""

from __future__ import annotations

import jax.numpy as jnp

#: Supported scalar kernel families (paper uses RBF + Matern).
KERNEL_KINDS = ("rbf", "matern12", "matern32", "matern52")

# Numerical floor used before sqrt so gradients / values stay finite at r=0.
_EPS = 1e-12


def sqdist_vector(theta, hist):
    """Squared euclidean distances ||theta - hist_tau||^2 for each row.

    theta: (D,), hist: (T, D)  ->  (T,)
    """
    diff = hist - theta[None, :]
    return jnp.sum(diff * diff, axis=-1)


def sqdist_matrix(hist):
    """Pairwise squared distances of history rows. hist: (T, D) -> (T, T)."""
    diff = hist[:, None, :] - hist[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def kernel_from_sqdist(r2, lengthscale, kind="matern52"):
    """Map squared distances to unit-amplitude kernel values.

    r2: any shape, lengthscale: scalar (>0), kind in KERNEL_KINDS.
    """
    r2 = jnp.maximum(r2, 0.0)
    if kind == "rbf":
        return jnp.exp(-0.5 * r2 / (lengthscale * lengthscale))
    r = jnp.sqrt(r2 + _EPS) / lengthscale
    if kind == "matern12":
        return jnp.exp(-r)
    if kind == "matern32":
        s = jnp.sqrt(3.0) * r
        return (1.0 + s) * jnp.exp(-s)
    if kind == "matern52":
        s = jnp.sqrt(5.0) * r
        return (1.0 + s + s * s / 3.0) * jnp.exp(-s)
    raise ValueError(f"unknown kernel kind: {kind!r}")


def kernel_vector(theta, hist, lengthscale, kind="matern52"):
    """k_t(theta): (T,) kernel values against each history row."""
    return kernel_from_sqdist(sqdist_vector(theta, hist), lengthscale, kind)


def kernel_matrix(hist, lengthscale, kind="matern52"):
    """K_t: (T, T) Gram matrix over the history."""
    return kernel_from_sqdist(sqdist_matrix(hist), lengthscale, kind)


def weighted_combine(w, grads):
    """mu = w^T G.  w: (T,), grads: (T, d) -> (d,)."""
    return w @ grads


def gp_weights(theta_sub, hist_sub, lengthscale, sigma2, kind="matern52"):
    """Posterior weight vector w = (K_t + sigma^2 I)^{-1} k_t(theta).

    theta_sub: (Ds,) the query point restricted to the kernel dim-subset,
    hist_sub:  (T, Ds) history restricted to the same subset.
    Returns (w (T,), kvec (T,)).
    """
    kvec = kernel_vector(theta_sub, hist_sub, lengthscale, kind)
    kmat = kernel_matrix(hist_sub, lengthscale, kind)
    t = kmat.shape[0]
    a = kmat + sigma2 * jnp.eye(t, dtype=kmat.dtype)
    w = jnp.linalg.solve(a, kvec)
    return w, kvec


def gp_estimate(theta_sub, hist_sub, grads, lengthscale, sigma2, kind="matern52"):
    """Full kernelized gradient estimate (paper eq. (4) + Prop. 4.1).

    Returns (mu (d,), var (scalar)) where var is the shared per-dimension
    posterior variance  k(theta,theta) - k^T (K + sigma^2 I)^{-1} k .
    """
    w, kvec = gp_weights(theta_sub, hist_sub, lengthscale, sigma2, kind)
    mu = weighted_combine(w, grads)
    var = 1.0 - jnp.dot(kvec, w)  # unit-amplitude kernel: k(x,x) = 1
    return mu, var


def median_heuristic(hist_sub):
    """Median pairwise distance of the history — default lengthscale.

    Mirrors rust/src/gp/estimator.rs::median_heuristic. Returns a scalar
    that is 1.0 when the history has < 2 distinct points.
    """
    t = hist_sub.shape[0]
    if t < 2:
        return jnp.asarray(1.0, dtype=hist_sub.dtype)
    r2 = sqdist_matrix(hist_sub)
    iu = jnp.triu_indices(t, k=1)
    med = jnp.sqrt(jnp.maximum(jnp.median(r2[iu]), _EPS))
    return jnp.where(med > 0, med, 1.0)
