"""Layer-1 Pallas kernels for OptEx kernelized gradient estimation.

These are the compute hot-spots of the OptEx leader step (paper §4.1):

  * ``sqdist_vector_pallas``   — ||theta - H_tau||^2 for every history row,
                                 tiled over the (possibly huge) feature dim.
  * ``sqdist_matrix_pallas``   — pairwise history distances, same tiling.
  * ``weighted_combine_pallas``— mu = w^T G, tiled over the parameter dim d
                                 (d up to millions; T0 <= 256 rows).

The kernel *map* (RBF / Matern on the distances) is O(T0) work and is left
to plain jnp in the caller (`model.gp_estimate_fn`), where XLA fuses it.

TPU mapping (see DESIGN.md §Hardware-Adaptation): each kernel streams its
large axis HBM->VMEM in lane-aligned blocks (multiples of 128); partial
sums accumulate in the f32 output ref across sequential grid steps.
``interpret=True`` is mandatory on this CPU image — real-TPU lowering
emits Mosaic custom-calls the CPU PJRT plugin cannot execute.

Padding contract: callers may pass any D / d; wrappers zero-pad to the
block size. Zero padding is exact for squared distances (both operands
padded with zeros) and for the combine matvec (padded G columns are
dropped on slice-out).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-aligned default blocks, sized for grid-step amortization: each
# interpret-mode grid step costs one XLA while-loop iteration, so blocks
# are as large as VMEM allows (combine: (T0+1) x 64Ki x 4B stays under the
# ~16 MB/core VMEM budget up to T0 = 63; RL's T0 = 150 pairs with small d).
# Tuned in the perf pass (EXPERIMENTS.md §Perf P6): 512->4096 and
# 4096->65536 cut gp-artifact execution time ~2x.
DEFAULT_BLOCK_D = 4096
DEFAULT_BLOCK_COMBINE = 65536


def _pad_to(x, size, axis):
    """Zero-pad `x` along `axis` up to `size` (no-op when already there)."""
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _num_blocks(n, block):
    return (n + block - 1) // block


# ---------------------------------------------------------------------------
# sqdist_vector: theta (D,), hist (T, D) -> (T,)
# ---------------------------------------------------------------------------


def _sqdist_vector_kernel(theta_ref, hist_ref, out_ref):
    """One grid step: partial squared distances over a D-block."""
    i = pl.program_id(0)
    diff = hist_ref[...] - theta_ref[...][None, :]
    part = jnp.sum(diff * diff, axis=1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_d",))
def sqdist_vector_pallas(theta, hist, block_d=DEFAULT_BLOCK_D):
    """Tiled ||theta - hist_tau||^2. theta: (D,), hist: (T, D) -> (T,)."""
    t, d = hist.shape
    block_d = min(block_d, max(d, 1))
    dp = _num_blocks(d, block_d) * block_d
    theta_p = _pad_to(theta, dp, 0)
    hist_p = _pad_to(hist, dp, 1)
    grid = (_num_blocks(dp, block_d),)
    return pl.pallas_call(
        _sqdist_vector_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((t, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((t,), theta.dtype),
        interpret=True,
    )(theta_p, hist_p)


# ---------------------------------------------------------------------------
# sqdist_matrix: hist (T, D) -> (T, T)
# ---------------------------------------------------------------------------


def _sqdist_matrix_kernel(hist_ref, out_ref):
    i = pl.program_id(0)
    h = hist_ref[...]
    diff = h[:, None, :] - h[None, :, :]
    part = jnp.sum(diff * diff, axis=2)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_d",))
def sqdist_matrix_pallas(hist, block_d=DEFAULT_BLOCK_D):
    """Tiled pairwise squared distances. hist: (T, D) -> (T, T)."""
    t, d = hist.shape
    block_d = min(block_d, max(d, 1))
    dp = _num_blocks(d, block_d) * block_d
    hist_p = _pad_to(hist, dp, 1)
    grid = (_num_blocks(dp, block_d),)
    return pl.pallas_call(
        _sqdist_matrix_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((t, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((t, t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, t), hist.dtype),
        interpret=True,
    )(hist_p)


# ---------------------------------------------------------------------------
# weighted_combine: w (T,), grads (T, d) -> (d,)
# ---------------------------------------------------------------------------


def _weighted_combine_kernel(w_ref, g_ref, out_ref):
    # One d-block: out = w^T G_block. T0 is small so this is a VPU
    # broadcast-multiply-reduce, not an MXU matmul (DESIGN.md §HW-Adapt).
    out_ref[...] = jnp.sum(w_ref[...][:, None] * g_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("block_d",))
def weighted_combine_pallas(w, grads, block_d=DEFAULT_BLOCK_COMBINE):
    """Tiled mu = w^T G. w: (T,), grads: (T, d) -> (d,)."""
    t, d = grads.shape
    block_d = min(block_d, max(d, 1))
    dp = _num_blocks(d, block_d) * block_d
    grads_p = _pad_to(grads, dp, 1)
    grid = (_num_blocks(dp, block_d),)
    out = pl.pallas_call(
        _weighted_combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((t, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), grads.dtype),
        interpret=True,
    )(w, grads_p)
    return out[:d]
