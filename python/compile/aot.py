"""AOT lowering: JAX graphs -> HLO text artifacts + manifest.json.

This is the ONLY place python touches the build. Usage (via `make
artifacts` from the repo root):

    python -m compile.aot --out-dir ../artifacts --profile default

Interchange format is **HLO text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Profiles scale the experiment grid:
  * ``test``    — tiny shapes for cargo/pytest integration tests (seconds),
  * ``default`` — CI-scale figures (minutes per figure on one CPU),
  * ``paper``   — the paper's full dimensions (Appx B.2).

``artifacts/manifest.json`` records, per artifact: file name, input/output
shapes+dtypes, and metadata (workload family, parameter dim d, batch,
kernel kind, T0, ...). The rust runtime (rust/src/runtime/artifact.rs)
drives everything from this manifest; names are the contract.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """Lowered jax -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple — see load_hlo.rs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Artifact:
    """One lowerable graph: a callable + example input specs + metadata."""

    def __init__(self, name, fn, in_specs, meta):
        self.name = name
        self.fn = fn
        self.in_specs = in_specs
        self.meta = meta

    def lower(self):
        return jax.jit(self.fn).lower(*self.in_specs)


# ---------------------------------------------------------------------------
# Profile grids
# ---------------------------------------------------------------------------


def _gp_artifact(name, t0, dsub, d, kind="matern52", extra=None):
    fn = model.gp_estimate_fn(kind)
    meta = {"family": "gp_estimate", "t0": t0, "dsub": dsub, "d": d, "kernel": kind}
    meta.update(extra or {})
    return Artifact(
        name,
        fn,
        [spec((dsub,)), spec((t0, dsub)), spec((t0, d)), spec(()), spec(())],
        meta,
    )


def _synth_artifact(fn_name, d):
    return Artifact(
        f"synth_{fn_name}_d{d}",
        model.synth_value_and_grad(fn_name),
        [spec((d,))],
        {"family": "synth", "fn": fn_name, "d": d},
    )


def _mlp_artifact(name, cfg, batch):
    return Artifact(
        name,
        model.mlp_loss_grad_fn(cfg),
        [spec((cfg.dim,)), spec((batch, cfg.in_dim)), spec((batch, cfg.out_dim))],
        {
            "family": "mlp",
            "d": cfg.dim,
            "batch": batch,
            "in_dim": cfg.in_dim,
            "width": cfg.width,
            "out_dim": cfg.out_dim,
            "layers": cfg.layers,
        },
    )


def _tfm_artifact(name, cfg, batch):
    return Artifact(
        name,
        model.tfm_loss_grad_fn(cfg),
        [spec((cfg.dim,)), spec((batch, cfg.seq + 1), I32)],
        {
            "family": "tfm",
            "d": cfg.dim,
            "batch": batch,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "embed": cfg.embed,
            "heads": cfg.heads,
            "blocks": cfg.blocks,
        },
    )


def _qnet_artifacts(env, cfg, batch, gamma=0.95):
    d = cfg.dim
    train = Artifact(
        f"qnet_{env}_train",
        model.qnet_train_fn(cfg, gamma),
        [
            spec((d,)),
            spec((d,)),
            spec((batch, cfg.obs_dim)),
            spec((batch,), I32),
            spec((batch,)),
            spec((batch, cfg.obs_dim)),
            spec((batch,)),
        ],
        {
            "family": "qnet_train",
            "env": env,
            "d": d,
            "batch": batch,
            "obs_dim": cfg.obs_dim,
            "n_actions": cfg.n_actions,
            "hidden": cfg.hidden,
            "gamma": gamma,
        },
    )
    act = Artifact(
        f"qnet_{env}_act",
        model.qnet_act_fn(cfg),
        [spec((d,)), spec((1, cfg.obs_dim))],
        {
            "family": "qnet_act",
            "env": env,
            "d": d,
            "obs_dim": cfg.obs_dim,
            "n_actions": cfg.n_actions,
            "hidden": cfg.hidden,
        },
    )
    return [train, act]


# Classic-control dims (must match rust/src/rl/*.rs)
QNET_ENVS = {
    "cartpole": model.QNetConfig(4, 2, 64),
    "acrobot": model.QNetConfig(6, 3, 128),
    "mountaincar": model.QNetConfig(2, 3, 64),
}


def profile_artifacts(profile: str):
    arts = []
    if profile == "test":
        d = 64
        for fn in model.SYNTH_FNS:
            arts.append(_synth_artifact(fn, d))
        arts.append(_gp_artifact("gp_test", t0=4, dsub=32, d=d))
        arts.append(_gp_artifact("gp_test_rbf", t0=4, dsub=32, d=d, kind="rbf"))
        mcfg = model.MlpConfig(16, 8, 4, 3)
        arts.append(_mlp_artifact("mlp_test", mcfg, batch=8))
        arts.append(
            _gp_artifact("gp_mlp_test", t0=3, dsub=min(64, mcfg.dim), d=mcfg.dim)
        )
        tcfg = model.TfmConfig(vocab=32, seq=16, embed=32, heads=2, blocks=1)
        arts.append(_tfm_artifact("tfm_test", tcfg, batch=2))
        qcfg = model.QNetConfig(4, 2, 8)
        arts += _qnet_artifacts("test", qcfg, batch=16)
        return arts

    if profile == "default":
        d_synth = 10_000
        t0_synth = 20
        for fn in model.SYNTH_FNS:
            arts.append(_synth_artifact(fn, d_synth))
        arts.append(
            _gp_artifact(
                "gp_synth", t0=t0_synth, dsub=min(4096, d_synth), d=d_synth
            )
        )
        mnist = model.MlpConfig(784, 128, 10, 9)
        cifar = model.MlpConfig(3072, 160, 10, 10)
        tfm = model.TfmConfig(vocab=96, seq=64, embed=128, heads=4, blocks=2)
        b_img, b_txt = 128, 16
    elif profile == "paper":
        d_synth = 100_000
        t0_synth = 20
        for fn in model.SYNTH_FNS:
            arts.append(_synth_artifact(fn, d_synth))
        arts.append(_gp_artifact("gp_synth", t0=t0_synth, dsub=10_000, d=d_synth))
        # paper: d=978186 (MNIST 9-layer), d=2412298 (CIFAR 10-layer),
        # d=1626496 (transformer). Widths chosen to land closest.
        mnist = model.MlpConfig(784, 320, 10, 9)
        cifar = model.MlpConfig(3072, 390, 10, 10)
        tfm = model.TfmConfig(vocab=96, seq=128, embed=192, heads=4, blocks=4)
        b_img, b_txt = 512, 64
    else:
        raise SystemExit(f"unknown profile {profile!r}")

    arts.append(_mlp_artifact("mlp_mnist", mnist, b_img))
    arts.append(_mlp_artifact("mlp_cifar", cifar, b_img))
    arts.append(_tfm_artifact("tfm_char", tfm, b_txt))
    # Estimation artifacts matched to each workload (paper T0 values).
    arts.append(_gp_artifact("gp_mnist", t0=6, dsub=4096, d=mnist.dim))
    arts.append(_gp_artifact("gp_cifar", t0=6, dsub=4096, d=cifar.dim))
    arts.append(_gp_artifact("gp_tfm", t0=10, dsub=8192, d=tfm.dim))
    for env, qcfg in QNET_ENVS.items():
        arts += _qnet_artifacts(env, qcfg, batch=256)
        arts.append(
            _gp_artifact(f"gp_{env}", t0=150, dsub=min(2048, qcfg.dim), d=qcfg.dim)
        )
    return arts


# ---------------------------------------------------------------------------
# Emission
# ---------------------------------------------------------------------------


def _dtype_tag(dt):
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def emit(artifact: Artifact, out_dir: Path, force: bool):
    path = out_dir / f"{artifact.name}.hlo.txt"
    t0 = time.time()
    if path.exists() and not force:
        status = "cached"
    else:
        lowered = artifact.lower()
        text = to_hlo_text(lowered)
        path.write_text(text)
        status = f"{len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s"
    entry = {
        "name": artifact.name,
        "file": path.name,
        "inputs": [
            {"shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
            for s in artifact.in_specs
        ],
        "meta": artifact.meta,
    }
    print(f"  {artifact.name:28s} {status}")
    return entry


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default="default", choices=["test", "default", "paper"])
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    arts = profile_artifacts(args.profile)
    if args.only:
        arts = [a for a in arts if args.only in a.name]
    if args.list:
        for a in arts:
            print(a.name, a.meta)
        return 0

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    print(f"lowering {len(arts)} artifacts (profile={args.profile}) -> {out_dir}")
    entries = [emit(a, out_dir, args.force) for a in arts]
    # --only regenerates a subset: merge with the existing manifest so the
    # untouched artifacts stay registered.
    manifest_path = out_dir / "manifest.json"
    if args.only and manifest_path.exists():
        old_doc = json.loads(manifest_path.read_text())
        fresh = {e["name"] for e in entries}
        entries = [
            e for e in old_doc.get("artifacts", []) if e["name"] not in fresh
        ] + entries
        entries.sort(key=lambda e: e["name"])
    manifest = {"profile": args.profile, "artifacts": entries}
    manifest_path.write_text(json.dumps(manifest, indent=2))
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
