"""Custom-call-free dense linear algebra for lowered graphs.

``jnp.linalg.solve`` / ``lax.linalg.cholesky`` lower, on the CPU backend,
to LAPACK FFI custom-calls registered by *this* jaxlib — which the rust
runtime's xla_extension 0.5.1 does not know. Artifacts containing them
load but fail at execution. These routines use only elementwise ops,
matvecs and ``.at[]`` updates, so they lower to plain HLO that any PJRT
backend can run.

Sizes here are the OptEx local-history length T0 (<= 256), so the O(n)
trace-time Python loops produce modest graphs (~4 ops per row) and the
O(n^3/2) flops are negligible next to the d-sized combine.

Mirrored by rust/src/gp/cholesky.rs (the native path); both are checked
against each other through the HLO artifacts in rust integration tests.
"""

from __future__ import annotations

import jax.numpy as jnp


def cholesky(a):
    """Lower-triangular L with L L^T = a, for SPD a (n, n).

    Left-looking column Cholesky, unrolled at trace time over columns.
    """
    n = a.shape[0]
    idx = jnp.arange(n)
    l = jnp.zeros_like(a)
    for j in range(n):
        # c = a[:, j] - sum_{k<j} L[:, k] * L[j, k]
        if j == 0:
            c = a[:, 0]
        else:
            c = a[:, j] - l[:, :j] @ l[j, :j]
        ljj = jnp.sqrt(jnp.maximum(c[j], 1e-30))
        col = jnp.where(idx >= j, c / ljj, 0.0)
        l = l.at[:, j].set(col)
    return l


def solve_lower(l, b):
    """Solve L y = b for lower-triangular L. b: (n,)."""
    n = l.shape[0]
    idx = jnp.arange(n)
    y = b
    for j in range(n):
        yj = y[j] / l[j, j]
        y = y.at[j].set(yj)
        if j + 1 < n:
            y = y - jnp.where(idx > j, l[:, j] * yj, 0.0)
    return y


def solve_upper_t(l, y):
    """Solve L^T x = y for lower-triangular L (i.e. upper solve). y: (n,)."""
    n = l.shape[0]
    idx = jnp.arange(n)
    x = y
    for j in reversed(range(n)):
        xj = x[j] / l[j, j]
        x = x.at[j].set(xj)
        if j > 0:
            x = x - jnp.where(idx < j, l[j, :] * xj, 0.0)
    return x


def chol_solve(a, b):
    """Solve a x = b for SPD a via Cholesky. a: (n, n), b: (n,)."""
    l = cholesky(a)
    return solve_upper_t(l, solve_lower(l, b))
