"""Layer-2 JAX compute graphs for the OptEx reproduction.

Every graph in this module is written against a **flat f32 parameter
vector** so the Layer-3 rust coordinator can treat all workloads uniformly
as `theta in R^d` (the paper's problem setup, eq. (1)). Architectures
mirror Appx B.2 of the paper:

  * modified Ackley / Sphere / Rosenbrock synthetic functions (B.2.1),
  * 9-layer residual MLP for (fashion-)MNIST, 10-layer for CIFAR-10 (B.2.3),
  * a small decoder-only char transformer (B.2.3, Haiku-borrowed model),
  * a 2-hidden-layer DQN q-network (B.2.2),
  * the kernelized gradient-estimation graph (Sec. 4.1 / Prop. 4.1) built
    on the Layer-1 Pallas kernels.

These functions are lowered ONCE by ``aot.py`` to HLO text; python never
runs on the request path.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import linalg
from .kernels import gp_kernels, ref

# ---------------------------------------------------------------------------
# Flat-parameter plumbing
# ---------------------------------------------------------------------------


def shapes_size(shapes):
    """Total element count of a list of shapes."""
    return sum(int(math.prod(s)) for s in shapes)


def unflatten(flat, shapes):
    """Split a flat (d,) vector into tensors with the given shapes."""
    out, off = [], 0
    for s in shapes:
        n = int(math.prod(s))
        out.append(flat[off : off + n].reshape(s))
        off += n
    return out


def init_flat(shapes, seed, scale="glorot"):
    """Reference initializer (rust owns init at runtime; this exists for
    python-side tests and notebooks)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    for s in shapes:
        key, sub = jax.random.split(key)
        if len(s) == 2 and scale == "glorot":
            lim = math.sqrt(6.0 / (s[0] + s[1]))
            parts.append(jax.random.uniform(sub, s, jnp.float32, -lim, lim).ravel())
        else:
            parts.append(jnp.zeros(int(math.prod(s)), jnp.float32))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Synthetic functions (paper Appx B.2.1 — modified forms)
# ---------------------------------------------------------------------------


def ackley(theta):
    s1 = jnp.sqrt(jnp.mean(theta * theta) + 1e-12)
    s2 = jnp.mean(jnp.cos(2.0 * jnp.pi * theta))
    return -20.0 * jnp.exp(-0.2 * s1) - jnp.exp(s2) + 20.0 + jnp.e


def sphere(theta):
    return jnp.sqrt(jnp.mean(theta * theta) + 1e-12)


def rosenbrock(theta):
    d = theta.shape[0]
    a = theta[1:]
    b = theta[:-1]
    return jnp.sum(100.0 * (a - b) ** 2 + (1.0 - b) ** 2) / d


SYNTH_FNS = {"ackley": ackley, "sphere": sphere, "rosenbrock": rosenbrock}


def synth_value_and_grad(name):
    """(theta (d,)) -> (f (), grad (d,)) for a synthetic function."""
    fn = SYNTH_FNS[name]

    def vag(theta):
        f, g = jax.value_and_grad(fn)(theta)
        return f, g

    return vag


# ---------------------------------------------------------------------------
# Residual MLP classifier (paper Appx B.2.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    """`layers` counts Linear layers incl. input+output (paper: 9 / 10)."""

    in_dim: int
    width: int
    out_dim: int
    layers: int

    @property
    def shapes(self):
        s = [(self.in_dim, self.width), (self.width,)]
        for _ in range(self.layers - 2):
            s += [(self.width, self.width), (self.width,)]
        s += [(self.width, self.out_dim), (self.out_dim,)]
        return s

    @property
    def dim(self):
        return shapes_size(self.shapes)


def mlp_logits(cfg: MlpConfig, flat, x):
    """Forward pass: relu MLP with identity skip connections on the
    equal-width hidden blocks (He et al. style residuals, paper B.2.3)."""
    parts = unflatten(flat, cfg.shapes)
    h = jnp.maximum(x @ parts[0] + parts[1], 0.0)
    for i in range(cfg.layers - 2):
        w, b = parts[2 + 2 * i], parts[3 + 2 * i]
        h = jnp.maximum(h @ w + b, 0.0) + h  # residual hidden block
    w, b = parts[-2], parts[-1]
    return h @ w + b


def mlp_loss_grad_fn(cfg: MlpConfig):
    """(flat (d,), x (B,in), y (B,out) one-hot) -> (loss, grad (d,), acc)."""

    def loss_fn(flat, x, y):
        logits = mlp_logits(cfg, flat, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.sum(y * logp, axis=-1))
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == jnp.argmax(y, -1)).astype(jnp.float32)
        )
        return loss, acc

    def vag(flat, x, y):
        (loss, acc), grad = jax.value_and_grad(loss_fn, has_aux=True)(flat, x, y)
        return loss, grad, acc

    return vag


# ---------------------------------------------------------------------------
# Decoder-only char transformer (paper Appx B.2.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TfmConfig:
    vocab: int = 96
    seq: int = 128
    embed: int = 192
    heads: int = 4
    blocks: int = 4

    @property
    def shapes(self):
        e = self.embed
        s = [(self.vocab, e), (self.seq, e)]  # token + positional embeddings
        for _ in range(self.blocks):
            s += [
                (e,), (e,),            # ln1 scale, bias
                (e, 3 * e), (3 * e,),  # fused qkv
                (e, e), (e,),          # attn out proj
                (e,), (e,),            # ln2 scale, bias
                (e, 4 * e), (4 * e,),  # mlp up
                (4 * e, e), (e,),      # mlp down
            ]
        s += [(e,), (e,)]  # final ln
        s += [(e, self.vocab), (self.vocab,)]  # lm head (untied)
        return s

    @property
    def dim(self):
        return shapes_size(self.shapes)


def _layernorm(x, scale, bias):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.mean((x - m) ** 2, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-5) * scale + bias


def _gelu(x):
    # tanh approximation: avoids erf (keeps the lowered HLO free of chlo
    # decompositions that differ across XLA versions).
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def tfm_logits(cfg: TfmConfig, flat, tokens):
    """tokens: (B, L) int32 -> logits (B, L, vocab)."""
    parts = unflatten(flat, cfg.shapes)
    it = iter(parts)
    tok_emb = next(it)
    pos_emb = next(it)
    b, l = tokens.shape
    e, h = cfg.embed, cfg.heads
    hd = e // h
    x = tok_emb[tokens] + pos_emb[None, :l, :]
    mask = jnp.tril(jnp.ones((l, l), jnp.float32))
    neg = jnp.float32(-1e9)
    for _ in range(cfg.blocks):
        ln1s, ln1b = next(it), next(it)
        wqkv, bqkv = next(it), next(it)
        wo, bo = next(it), next(it)
        ln2s, ln2b = next(it), next(it)
        w1, b1 = next(it), next(it)
        w2, b2 = next(it), next(it)
        y = _layernorm(x, ln1s, ln1b)
        qkv = y @ wqkv + bqkv  # (B, L, 3E)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
        att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # (B,H,L,L)
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(b, l, e)
        x = x + o @ wo + bo
        y = _layernorm(x, ln2s, ln2b)
        x = x + _gelu(y @ w1 + b1) @ w2 + b2
    fs, fb = next(it), next(it)
    wl, bl = next(it), next(it)
    x = _layernorm(x, fs, fb)
    return x @ wl + bl


def tfm_loss_grad_fn(cfg: TfmConfig):
    """(flat (d,), tokens (B, L+1) int32) -> (loss, grad (d,))."""

    def loss_fn(flat, tokens):
        x = tokens[:, :-1]
        y = tokens[:, 1:]
        logits = tfm_logits(cfg, flat, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    def vag(flat, tokens):
        loss, grad = jax.value_and_grad(loss_fn)(flat, tokens)
        return loss, grad

    return vag


# ---------------------------------------------------------------------------
# DQN q-network (paper Appx B.2.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QNetConfig:
    obs_dim: int
    n_actions: int
    hidden: int = 64  # paper: 64 or 128 per task

    @property
    def shapes(self):
        h = self.hidden
        return [
            (self.obs_dim, h), (h,),
            (h, h), (h,),
            (h, self.n_actions), (self.n_actions,),
        ]

    @property
    def dim(self):
        return shapes_size(self.shapes)


def qnet_forward(cfg: QNetConfig, flat, obs):
    """obs: (B, O) -> q-values (B, A)."""
    w1, b1, w2, b2, w3, b3 = unflatten(flat, cfg.shapes)
    h = jnp.maximum(obs @ w1 + b1, 0.0)
    h = jnp.maximum(h @ w2 + b2, 0.0)
    return h @ w3 + b3


def qnet_act_fn(cfg: QNetConfig):
    """(flat (d,), obs (B, O)) -> q (B, A) — greedy action-selection graph."""

    def act(flat, obs):
        return (qnet_forward(cfg, flat, obs),)

    return act


def qnet_train_fn(cfg: QNetConfig, gamma: float = 0.95):
    """One DQN TD step (Mnih et al. 2015 target-network form).

    (flat, target_flat, obs (B,O), act (B,) i32, rew (B,), next_obs (B,O),
     done (B,)) -> (loss, grad (d,))
    """

    def loss_fn(flat, target_flat, obs, act, rew, next_obs, done):
        q = qnet_forward(cfg, flat, obs)
        qa = jnp.take_along_axis(q, act[:, None], axis=1)[:, 0]
        qn = qnet_forward(cfg, target_flat, next_obs)
        tgt = rew + gamma * (1.0 - done) * jnp.max(qn, axis=1)
        tgt = jax.lax.stop_gradient(tgt)
        err = qa - tgt
        return jnp.mean(err * err)

    def vag(flat, target_flat, obs, act, rew, next_obs, done):
        loss, grad = jax.value_and_grad(loss_fn)(
            flat, target_flat, obs, act, rew, next_obs, done
        )
        return loss, grad

    return vag


# ---------------------------------------------------------------------------
# Kernelized gradient estimation (paper Sec. 4.1, Prop. 4.1) — THE hot path
# ---------------------------------------------------------------------------


def gp_estimate_fn(kind="matern52"):
    """Build the OptEx estimation graph on the Layer-1 Pallas kernels.

    (theta_sub (Ds,), hist_sub (T0, Ds), grads (T0, d),
     lengthscale (), sigma2 ()) -> (mu (d,), var (1,))

    lengthscale / sigma2 are runtime scalar inputs so ONE artifact per
    (T0, Ds, d) shape serves every hyperparameter setting. The T0 x T0
    solve uses the custom-call-free Cholesky in `linalg` (see its
    docstring for why jnp.linalg.solve is off-limits here).
    """

    def est(theta_sub, hist_sub, grads, lengthscale, sigma2):
        t0 = hist_sub.shape[0]
        r2v = gp_kernels.sqdist_vector_pallas(theta_sub, hist_sub)
        r2m = gp_kernels.sqdist_matrix_pallas(hist_sub)
        kvec = ref.kernel_from_sqdist(r2v, lengthscale, kind)
        kmat = ref.kernel_from_sqdist(r2m, lengthscale, kind)
        a = kmat + (sigma2 + 1e-6) * jnp.eye(t0, dtype=kmat.dtype)
        w = linalg.chol_solve(a, kvec)
        mu = gp_kernels.weighted_combine_pallas(w, grads)
        var = (1.0 - jnp.dot(kvec, w)).reshape(1)
        return mu, var

    return est
