#!/usr/bin/env bash
# ISSUE 10 satellite: real-binary router smoke. `optex router` fronts
# TWO real `optex serve` worker processes; this script drives the whole
# client surface over bash's /dev/tcp — stats across the fleet, a
# paused submit, a live migration between workers (export → import →
# route flip behind one stable client id), resume, completion with the
# full iteration budget, and a theta-carrying result — then shuts the
# fleet down cleanly.
#
# The heavy acceptance matrices (K = 8 byte-identity, mid-run migration
# push ordering, SIGKILL recovery) live in the router_integration suite;
# this script asserts the operator-facing path against the shipped
# binary with no test harness in the loop.
#
# Usage: tools/router_smoke.sh [path-to-optex-binary]
set -euo pipefail

BIN="${1:-target/release/optex}"
DIR="$(mktemp -d /tmp/optex_router_smoke.XXXXXX)"
PORT=$((20000 + RANDOM % 20000))
ADDR="127.0.0.1:${PORT}"
ROUTER_PID=""

cleanup() {
  [ -n "${ROUTER_PID}" ] && kill -9 "${ROUTER_PID}" 2>/dev/null || true
  rm -rf "${DIR}"
}
trap cleanup EXIT

fail() { echo "router_smoke: FAIL: $*" >&2; exit 1; }

# One JSONL request/response exchange over /dev/tcp (fresh connection
# per request — protocol version is per-connection, so these all speak
# v1; the v2 envelope is covered by the wire_conformance suite).
request() {
  local req="$1" reply
  exec 3<>"/dev/tcp/127.0.0.1/${PORT}" || fail "connecting ${ADDR}"
  printf '%s\n' "${req}" >&3
  IFS= read -r reply <&3 || fail "no reply to: ${req}"
  exec 3<&- 3>&-
  printf '%s' "${reply}"
}

wait_port() {
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${PORT}") 2>/dev/null; then
      exec 3<&- 3>&- 2>/dev/null || true
      return 0
    fi
    sleep 0.1
  done
  fail "router never came up on ${ADDR}"
}

echo "router_smoke: phase 1 — router over two real workers"
"${BIN}" router --addr "${ADDR}" --workers 2 --dir "${DIR}" &
ROUTER_PID=$!
wait_port

REPLY=$(request '{"cmd":"stats"}')
echo "router_smoke: stats -> ${REPLY}"
case "${REPLY}" in
  *'"router":true'*) ;;
  *) fail "stats did not identify the router tier: ${REPLY}" ;;
esac
ALIVE=$(printf '%s' "${REPLY}" | grep -o '"alive":true' | wc -l)
[ "${ALIVE}" -eq 2 ] || fail "expected 2 live workers, saw ${ALIVE}: ${REPLY}"

echo "router_smoke: phase 2 — paused submit, then live migration"
REPLY=$(request '{"cmd":"submit","config":{"workload":"rosenbrock","synth_dim":64,"steps":6,"seed":9,"optex.threads":1},"paused":true}')
echo "router_smoke: submit -> ${REPLY}"
case "${REPLY}" in
  *'"state":"paused"'*) ;;
  *) fail "paused submit not acknowledged: ${REPLY}" ;;
esac

REPLY=$(request '{"cmd":"migrate","id":1}')
echo "router_smoke: migrate -> ${REPLY}"
case "${REPLY}" in
  *'"migrated":true'*) ;;
  *) fail "migration refused: ${REPLY}" ;;
esac
case "${REPLY}" in
  *'"state":"paused"'*) ;;
  *) fail "a paused session must stay paused across the move: ${REPLY}" ;;
esac

echo "router_smoke: phase 3 — resume on the destination, run to done"
REPLY=$(request '{"cmd":"resume","id":1}')
case "${REPLY}" in
  *'"ok":true'*) ;;
  *) fail "resume after migration refused: ${REPLY}" ;;
esac

for _ in $(seq 1 300); do
  REPLY=$(request '{"cmd":"status","id":1}')
  case "${REPLY}" in
    *'"state":"done"'*) break ;;
    *'"state":"failed"'*) fail "session failed after migration: ${REPLY}" ;;
  esac
  sleep 0.1
done
case "${REPLY}" in
  *'"state":"done"'*) ;;
  *) fail "session never finished after migration: ${REPLY}" ;;
esac
case "${REPLY}" in
  *'"iters":6'*) ;;
  *) fail "migrated session did not run the full budget: ${REPLY}" ;;
esac

REPLY=$(request '{"cmd":"result","id":1,"theta":true}')
case "${REPLY}" in
  *'"theta":['*) ;;
  *) fail "result did not carry the iterate: ${REPLY}" ;;
esac

REPLY=$(request '{"cmd":"shutdown"}')
echo "router_smoke: shutdown -> ${REPLY}"
wait "${ROUTER_PID}" 2>/dev/null || true
ROUTER_PID=""

echo "router_smoke: OK — fleet up, session migrated live, byte surface intact"
