#!/usr/bin/env bash
# ISSUE 9 satellite: observability smoke against a REAL server process.
# A release `optex serve` is started with `--metrics-addr`; a session is
# submitted over the JSONL wire and run to Done; then the script asserts
# that (1) the `stats` verb answers a snapshot whose iteration counter
# is nonzero and matches the work done, (2) the Prometheus-style text
# exposition on the second listener parses line-for-line and carries the
# same nonzero counter, and (3) the `trace` verb answers for a live id.
#
# The in-process halves of these assertions live in
# rust/tests/serve_integration.rs and rust/tests/fault_injection.rs;
# this script pins the real-binary, real-second-listener path.
#
# Usage: tools/obs_smoke.sh [path-to-optex-binary]
set -euo pipefail

BIN="${1:-target/release/optex}"
DIR="$(mktemp -d /tmp/optex_obs_smoke.XXXXXX)"
PORT=$((20000 + RANDOM % 20000))
MPORT=$((PORT + 1))
ADDR="127.0.0.1:${PORT}"
MADDR="127.0.0.1:${MPORT}"
STEPS=6
SERVER_PID=""

cleanup() {
  [ -n "${SERVER_PID}" ] && kill -9 "${SERVER_PID}" 2>/dev/null || true
  rm -rf "${DIR}"
}
trap cleanup EXIT

fail() { echo "obs_smoke: FAIL: $*" >&2; exit 1; }

# One JSONL request/response exchange over bash's /dev/tcp (no netcat
# dependency on the runner).
request() {
  local req="$1" reply
  exec 3<>"/dev/tcp/127.0.0.1/${PORT}" || fail "connecting ${ADDR}"
  printf '%s\n' "${req}" >&3
  IFS= read -r reply <&3 || fail "no reply to: ${req}"
  exec 3<&- 3>&-
  printf '%s' "${reply}"
}

wait_port() {
  local port="$1"
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${port}") 2>/dev/null; then
      exec 3<&- 3>&- 2>/dev/null || true
      return 0
    fi
    sleep 0.1
  done
  fail "server never came up on 127.0.0.1:${port}"
}

echo "obs_smoke: phase 1 — server with a metrics listener"
"${BIN}" serve --addr "${ADDR}" --metrics-addr "${MADDR}" --threads 1 \
  --set "serve.ckpt_dir=${DIR}" &
SERVER_PID=$!
wait_port "${PORT}"
wait_port "${MPORT}"

REPLY=$(request "{\"cmd\":\"submit\",\"config\":{\"workload\":\"sphere\",\"synth_dim\":64,\"steps\":${STEPS},\"seed\":5,\"optex.threads\":1}}")
echo "obs_smoke: submit -> ${REPLY}"
case "${REPLY}" in
  *'"ok":true'*) ;;
  *) fail "submit refused: ${REPLY}" ;;
esac

for _ in $(seq 1 300); do
  REPLY=$(request '{"cmd":"status","id":1}')
  case "${REPLY}" in
    *'"state":"done"'*) break ;;
    *'"state":"failed"'*) fail "session failed: ${REPLY}" ;;
  esac
  sleep 0.1
done
case "${REPLY}" in
  *'"state":"done"'*) ;;
  *) fail "session never finished: ${REPLY}" ;;
esac

echo "obs_smoke: phase 2 — the stats verb counts the iterations"
REPLY=$(request '{"cmd":"stats"}')
echo "obs_smoke: stats -> ${REPLY}"
case "${REPLY}" in
  *'"ok":true'*) ;;
  *) fail "stats refused: ${REPLY}" ;;
esac
ITERS=$(printf '%s' "${REPLY}" \
  | sed -n 's/.*"optex_iterations_total":\([0-9][0-9]*\).*/\1/p')
[ -n "${ITERS}" ] || fail "stats lacks optex_iterations_total: ${REPLY}"
[ "${ITERS}" -ge "${STEPS}" ] \
  || fail "stats counted ${ITERS} iterations, ran ${STEPS}"

echo "obs_smoke: phase 3 — the exposition parses and agrees"
EXPO=$(exec 3<>"/dev/tcp/127.0.0.1/${MPORT}" \
  && printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3 && cat <&3) \
  || fail "scraping ${MADDR}"
BODY=$(printf '%s\n' "${EXPO}" | sed '1,/^[[:space:]]*$/d')
printf '%s\n' "${BODY}" | grep -q '^# TYPE optex_iterations_total counter$' \
  || fail "exposition lacks the TYPE line:
${BODY}"
# every sample line must be `name[{labels}] <number>`
printf '%s\n' "${BODY}" | grep -v '^#' | grep -v '^$' \
  | grep -qvE '^[a-z_]+(\{[^}]*\})? -?[0-9]+(\.[0-9]+)?([eE][+-][0-9]+)?$' \
  && fail "unparseable exposition line(s):
$(printf '%s\n' "${BODY}" | grep -v '^#' | grep -v '^$' \
  | grep -vE '^[a-z_]+(\{[^}]*\})? -?[0-9]+(\.[0-9]+)?([eE][+-][0-9]+)?$')"
SCRAPED=$(printf '%s\n' "${BODY}" \
  | sed -n 's/^optex_iterations_total \([0-9][0-9]*\).*/\1/p')
[ -n "${SCRAPED}" ] || fail "exposition lacks optex_iterations_total:
${BODY}"
[ "${SCRAPED}" -ge "${STEPS}" ] \
  || fail "exposition reports ${SCRAPED} iterations, ran ${STEPS}"

echo "obs_smoke: phase 4 — the trace verb answers for a live id"
REPLY=$(request '{"cmd":"trace","id":1}')
echo "obs_smoke: trace -> ${REPLY}"
case "${REPLY}" in
  *'"ok":true'*'"trace":['*) ;;
  *) fail "trace refused: ${REPLY}" ;;
esac

REPLY=$(request '{"cmd":"shutdown"}')
echo "obs_smoke: shutdown -> ${REPLY}"
wait "${SERVER_PID}" 2>/dev/null || true
SERVER_PID=""

echo "obs_smoke: OK — stats, exposition and trace all answer with live counters"
