//! BENCH_TREND.md generator + bench regression gate.
//!
//! **Trend** (default, ROADMAP PR-3 follow-up, closed in ISSUE 4): folds
//! every `BENCH_*.json` summary in the working directory — one per PR,
//! written by `bench_estimation` — into a single metric × PR markdown
//! table, so the perf trajectory across PRs is one artifact instead of N
//! files to diff by hand. CI runs this right after the bench step and
//! uploads `BENCH_TREND.md` next to the JSON summaries.
//!
//! **Gate** (`--check`, ISSUE 5 satellite): compares fresh `BENCH_*.json`
//! summaries against committed baselines and FAILS on a > 25% regression
//! of any pinned metric:
//!
//! | metric | direction |
//! |---|---|
//! | `store_vs_seed[...].combine_store_ns_per_elem` | lower is better |
//! | `combine_pool[...].ns_per_elem`                | lower is better |
//! | `store_vs_seed[...].store_flatten_bytes_per_iter` (copies/iter) | lower is better (zero must STAY zero) |
//! | `serve_throughput[k=8,...].steps_per_sec`      | higher is better |
//! | `serve_throughput[k=8,steppers=8,...].steps_per_sec` (ISSUE 8 stepper-pool payoff) | higher is better |
//! | `obs_overhead[k=8,...].steps_per_sec` (ISSUE 9 instrumented throughput) | higher is better |
//!
//! Usage: `bench_trend --check [--fresh DIR] [--baseline DIR]`
//! (defaults: fresh = `.`, baseline = `baselines/`). Metrics without a
//! committed baseline pass with a notice — seed `baselines/` from a
//! trusted CI run's `bench-summary` artifact via
//! `bench_trend --write-baseline [--fresh DIR]`. Escape hatch for noisy
//! runners: `OPTEX_BENCH_BASELINE_SKIP=1` downgrades failures to
//! warnings (the job stays green, the report still prints).
//!
//! Schema expected (what `bench_estimation` writes):
//! `{"pr": N, "bench": ..., "rows": [{"section": ..., <coord/metric fields>}]}`
//! Grid-coordinate fields (`t0`, `d`, `n`, `dsub`, `k`, `steps_per_session`)
//! become part of the metric's row label; every other numeric field is a
//! measurement column.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};
use optex::util::json::Json;

/// Fields that locate a grid cell rather than measure it.
const COORDS: &[&str] = &["t0", "d", "n", "dsub", "k", "steppers", "steps_per_session"];

/// Relative regression threshold for the gate (25%).
const GATE_TOLERANCE: f64 = 0.25;

/// Absolute slack so a zero baseline is not an automatic failure for
/// zero fresh values (floating-point noise), while any REAL increase
/// from zero (e.g. copies/iter) still trips the gate.
const GATE_ABS_EPS: f64 = 1e-9;

/// One pinned (gated) metric family.
struct Pinned {
    section: &'static str,
    field: &'static str,
    higher_is_better: bool,
    /// Only gate cells where EVERY listed coordinate has the listed
    /// value (empty = gate the whole section/field family). Multi-
    /// coordinate since ISSUE 8, whose payoff cell is located by two
    /// coordinates at once (`k` and `steppers`).
    coord_filter: &'static [(&'static str, f64)],
}

/// The gate's metric list (ISSUE 5: combine ns/elem, copies/iter,
/// K=8 serve steps/s; ISSUE 8: the K=8 stepper-pool throughput cell;
/// ISSUE 9: the instrumented K=8 obs-overhead cell).
/// Order matters only for documentation — `pinned_match` is first-hit,
/// so keep more specific filters before broader ones.
const PINNED: &[Pinned] = &[
    Pinned {
        section: "store_vs_seed",
        field: "combine_store_ns_per_elem",
        higher_is_better: false,
        coord_filter: &[],
    },
    Pinned {
        section: "combine_pool",
        field: "ns_per_elem",
        higher_is_better: false,
        coord_filter: &[],
    },
    Pinned {
        section: "store_vs_seed",
        field: "store_flatten_bytes_per_iter",
        higher_is_better: false,
        coord_filter: &[],
    },
    // ISSUE 8 payoff pin: the concurrent stepper pool's K=8 aggregate
    // throughput (recorded ≥ 2x its steppers=1 row at seed time — this
    // gate keeps the win from quietly eroding).
    Pinned {
        section: "serve_throughput",
        field: "steps_per_sec",
        higher_is_better: true,
        coord_filter: &[("k", 8.0), ("steppers", 8.0)],
    },
    Pinned {
        section: "serve_throughput",
        field: "steps_per_sec",
        higher_is_better: true,
        coord_filter: &[("k", 8.0)],
    },
    // ISSUE 9 overhead pin: K=8 steps/s with the metrics registry live.
    // The baseline was recorded within 5% of the obs-disabled row in the
    // same BENCH_9 cell, so a later instrumentation change that slows the
    // hot path shows up here as a throughput regression.
    Pinned {
        section: "obs_overhead",
        field: "steps_per_sec",
        higher_is_better: true,
        coord_filter: &[("k", 8.0)],
    },
];

fn is_coord(k: &str) -> bool {
    COORDS.contains(&k)
}

fn coord_label(obj: &BTreeMap<String, Json>) -> String {
    let mut parts = Vec::new();
    for c in COORDS {
        if let Some(v) = obj.get(*c).and_then(Json::as_f64) {
            parts.push(format!("{c}={v}"));
        }
    }
    parts.join(",")
}

fn fmt_metric(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// `BENCH_<pr>.json` files in a directory, sorted by PR number.
fn bench_files(dir: &Path) -> Result<Vec<(u64, std::path::PathBuf)>> {
    let mut files = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?
    {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(stem) = name.strip_prefix("BENCH_").and_then(|s| s.strip_suffix(".json"))
        {
            if let Ok(pr) = stem.parse::<u64>() {
                files.push((pr, entry.path()));
            }
        }
    }
    files.sort();
    Ok(files)
}

/// One measurement: section, coords (label + raw values), field, value.
struct Row {
    section: String,
    coords: String,
    coord_vals: BTreeMap<String, f64>,
    field: String,
    value: f64,
}

impl Row {
    fn label(&self) -> String {
        if self.coords.is_empty() {
            format!("{}.{}", self.section, self.field)
        } else {
            format!("{}[{}].{}", self.section, self.coords, self.field)
        }
    }
}

/// Flatten every `BENCH_*.json` in `dir` into measurement rows (also
/// returns the per-PR file list for the trend table header).
fn collect_rows(dir: &Path) -> Result<(Vec<(u64, String)>, Vec<(u64, Row)>)> {
    let files = bench_files(dir)?;
    let mut rows_out = Vec::new();
    let mut names = Vec::new();
    for (pr, path) in &files {
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let doc = Json::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
        let rows = doc
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("{name}: missing \"rows\" array"))?;
        for row in rows {
            let obj = row
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("{name}: non-object row"))?;
            let section = obj
                .get("section")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("{name}: row without section"))?
                .to_string();
            let coords = coord_label(obj);
            let coord_vals: BTreeMap<String, f64> = obj
                .iter()
                .filter(|(k, _)| is_coord(k))
                .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                .collect();
            for (k, v) in obj {
                if k == "section" || is_coord(k) {
                    continue;
                }
                let Some(val) = v.as_f64() else { continue };
                rows_out.push((
                    *pr,
                    Row {
                        section: section.clone(),
                        coords: coords.clone(),
                        coord_vals: coord_vals.clone(),
                        field: k.clone(),
                        value: val,
                    },
                ));
            }
        }
        names.push((*pr, name));
    }
    Ok((names, rows_out))
}

// -- trend table --------------------------------------------------------------

fn write_trend(dir: &Path) -> Result<()> {
    let (files, rows) = collect_rows(dir)?;
    if files.is_empty() {
        bail!("no BENCH_*.json files in {}", dir.display());
    }
    // metric label -> (pr -> value)
    let mut table: BTreeMap<String, BTreeMap<u64, f64>> = BTreeMap::new();
    for (pr, row) in &rows {
        table.entry(row.label()).or_default().insert(*pr, row.value);
    }
    let prs: Vec<u64> = files.iter().map(|(pr, _)| *pr).collect();
    let mut out = String::from("# Bench trend (metric × PR)\n\n");
    out.push_str(
        "Generated by `cargo run --bin bench_trend` from the `BENCH_*.json` \
         summaries `bench_estimation` emits (one per PR). Empty cells mean the \
         metric did not exist in that PR.\n\n",
    );
    out.push_str("| metric |");
    for pr in &prs {
        out.push_str(&format!(" PR {pr} |"));
    }
    out.push_str("\n|---|");
    for _ in &prs {
        out.push_str("---:|");
    }
    out.push('\n');
    for (label, by_pr) in &table {
        out.push_str(&format!("| `{label}` |"));
        for pr in &prs {
            match by_pr.get(pr) {
                Some(v) => out.push_str(&format!(" {} |", fmt_metric(*v))),
                None => out.push_str("  |"),
            }
        }
        out.push('\n');
    }
    std::fs::write("BENCH_TREND.md", &out)?;
    println!(
        "wrote BENCH_TREND.md ({} metrics × {} PRs: {})",
        table.len(),
        prs.len(),
        files.iter().map(|(_, n)| n.as_str()).collect::<Vec<_>>().join(", ")
    );
    Ok(())
}

// -- regression gate ----------------------------------------------------------

/// One gated comparison.
struct GateCheck {
    label: String,
    fresh: f64,
    baseline: f64,
    regressed: bool,
}

/// Gate outcome over two directories of summaries.
struct GateReport {
    checks: Vec<GateCheck>,
    /// Pinned fresh metrics with no committed baseline (pass + notice).
    unbaselined: Vec<String>,
}

impl GateReport {
    fn regressions(&self) -> impl Iterator<Item = &GateCheck> {
        self.checks.iter().filter(|c| c.regressed)
    }
}

fn pinned_match(p: &Pinned, row: &Row) -> bool {
    if row.section != p.section || row.field != p.field {
        return false;
    }
    p.coord_filter
        .iter()
        .all(|(c, v)| row.coord_vals.get(*c).copied() == Some(*v))
}

/// A > 25% move in the harmful direction (with absolute slack so a zero
/// baseline tolerates exactly zero — any real increase from 0 fails).
fn is_regression(fresh: f64, baseline: f64, higher_is_better: bool) -> bool {
    if higher_is_better {
        fresh < baseline * (1.0 - GATE_TOLERANCE) - GATE_ABS_EPS
    } else {
        fresh > baseline * (1.0 + GATE_TOLERANCE) + GATE_ABS_EPS
    }
}

/// Compare every pinned metric in `fresh_dir` against `baseline_dir`.
fn check_dirs(fresh_dir: &Path, baseline_dir: &Path) -> Result<GateReport> {
    let (_, fresh_rows) = collect_rows(fresh_dir)?;
    if fresh_rows.is_empty() {
        bail!("no BENCH_*.json summaries in {}", fresh_dir.display());
    }
    let baseline_rows = if baseline_dir.is_dir() {
        collect_rows(baseline_dir)?.1
    } else {
        Vec::new()
    };
    // (pr, label) -> baseline value
    let baseline: BTreeMap<(u64, String), f64> = baseline_rows
        .iter()
        .map(|(pr, r)| ((*pr, r.label()), r.value))
        .collect();
    let mut checks = Vec::new();
    let mut unbaselined = Vec::new();
    for (pr, row) in &fresh_rows {
        let Some(p) = PINNED.iter().find(|p| pinned_match(p, row)) else {
            continue;
        };
        let label = row.label();
        match baseline.get(&(*pr, label.clone())) {
            None => unbaselined.push(label),
            Some(&b) => checks.push(GateCheck {
                regressed: is_regression(row.value, b, p.higher_is_better),
                label,
                fresh: row.value,
                baseline: b,
            }),
        }
    }
    Ok(GateReport { checks, unbaselined })
}

fn run_check(fresh_dir: &Path, baseline_dir: &Path) -> Result<()> {
    let report = check_dirs(fresh_dir, baseline_dir)?;
    println!(
        "bench gate: {} pinned metric(s) checked against {} (tolerance {:.0}%)",
        report.checks.len(),
        baseline_dir.display(),
        GATE_TOLERANCE * 100.0
    );
    for c in &report.checks {
        println!(
            "  {} {}: fresh {} vs baseline {}",
            if c.regressed { "REGRESSED" } else { "ok       " },
            c.label,
            fmt_metric(c.fresh),
            fmt_metric(c.baseline)
        );
    }
    if !report.unbaselined.is_empty() {
        println!(
            "  {} pinned metric(s) have no committed baseline (passing; seed \
             baselines/ with `bench_trend --write-baseline` from a trusted run):",
            report.unbaselined.len()
        );
        for l in &report.unbaselined {
            println!("    no-baseline {l}");
        }
    }
    let n_bad = report.regressions().count();
    if n_bad > 0 {
        if std::env::var("OPTEX_BENCH_BASELINE_SKIP").is_ok() {
            println!(
                "bench gate: {n_bad} regression(s) IGNORED \
                 (OPTEX_BENCH_BASELINE_SKIP is set — noisy-runner escape hatch)"
            );
            return Ok(());
        }
        bail!(
            "bench gate: {n_bad} pinned metric(s) regressed > {:.0}% \
             (set OPTEX_BENCH_BASELINE_SKIP=1 to override on a noisy runner)",
            GATE_TOLERANCE * 100.0
        );
    }
    println!("bench gate: OK");
    Ok(())
}

/// Copy fresh summaries into the baseline directory (seeding/refresh).
fn write_baseline(fresh_dir: &Path, baseline_dir: &Path) -> Result<()> {
    let files = bench_files(fresh_dir)?;
    if files.is_empty() {
        bail!("no BENCH_*.json summaries in {}", fresh_dir.display());
    }
    std::fs::create_dir_all(baseline_dir)?;
    for (_, path) in &files {
        let dest = baseline_dir.join(path.file_name().unwrap());
        std::fs::copy(path, &dest)
            .with_context(|| format!("copying {} -> {}", path.display(), dest.display()))?;
        println!("baseline {}", dest.display());
    }
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut mode_check = false;
    let mut mode_write = false;
    let mut fresh = std::path::PathBuf::from(".");
    let mut baseline = std::path::PathBuf::from("baselines");
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--check" => mode_check = true,
            "--write-baseline" => mode_write = true,
            "--fresh" => fresh = it.next().context("--fresh needs a directory")?.into(),
            "--baseline" => {
                baseline = it.next().context("--baseline needs a directory")?.into()
            }
            other => bail!("unknown argument {other:?} (see tools/bench_trend.rs docs)"),
        }
    }
    if mode_check && mode_write {
        bail!("--check and --write-baseline are mutually exclusive");
    }
    if mode_check {
        run_check(&fresh, &baseline)
    } else if mode_write {
        write_baseline(&fresh, &baseline)
    } else {
        write_trend(&fresh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("optex_gate_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn summary(
        combine_ns: f64,
        copies: f64,
        steps_k8: f64,
        steps_k1: f64,
    ) -> String {
        format!(
            concat!(
                "{{\"pr\": 5, \"bench\": \"bench_estimation\", \"rows\": [\n",
                "  {{\"section\": \"store_vs_seed\", \"t0\": 64, \"d\": 10000, ",
                "\"combine_store_ns_per_elem\": {}, ",
                "\"store_flatten_bytes_per_iter\": {}}},\n",
                "  {{\"section\": \"serve_throughput\", \"k\": 8, \"d\": 2000, ",
                "\"steps_per_sec\": {}, \"latency_p50_ms\": 4.0}},\n",
                "  {{\"section\": \"serve_throughput\", \"k\": 1, \"d\": 2000, ",
                "\"steps_per_sec\": {}, \"latency_p50_ms\": 1.0}}\n",
                "]}}\n"
            ),
            combine_ns, copies, steps_k8, steps_k1
        )
    }

    #[test]
    fn within_tolerance_passes() {
        let fresh = dir("pass_fresh");
        let base = dir("pass_base");
        std::fs::write(base.join("BENCH_5.json"), summary(0.5, 0.0, 1000.0, 900.0))
            .unwrap();
        // 20% slower combine, 10% slower serve: inside the 25% band
        std::fs::write(fresh.join("BENCH_5.json"), summary(0.6, 0.0, 900.0, 500.0))
            .unwrap();
        let report = check_dirs(&fresh, &base).unwrap();
        assert_eq!(report.checks.len(), 3, "combine + copies + k=8 steps");
        assert_eq!(report.regressions().count(), 0);
        // k=1 steps_per_sec halved but is NOT pinned (only k=8 is)
        std::fs::remove_dir_all(&fresh).ok();
        std::fs::remove_dir_all(&base).ok();
    }

    /// ISSUE 5 acceptance: the negative test — an injected regression
    /// must demonstrably fail the gate.
    #[test]
    fn injected_regressions_fail() {
        let fresh = dir("fail_fresh");
        let base = dir("fail_base");
        std::fs::write(base.join("BENCH_5.json"), summary(0.5, 0.0, 1000.0, 900.0))
            .unwrap();
        // 2x slower combine AND 40% serve throughput drop AND copies/iter
        // jumping off zero: three regressions
        std::fs::write(
            fresh.join("BENCH_5.json"),
            summary(1.0, 2_560_000.0, 600.0, 900.0),
        )
        .unwrap();
        let report = check_dirs(&fresh, &base).unwrap();
        let bad: Vec<&str> =
            report.regressions().map(|c| c.label.as_str()).collect();
        assert_eq!(bad.len(), 3, "{bad:?}");
        assert!(bad.iter().any(|l| l.contains("combine_store_ns_per_elem")));
        assert!(bad.iter().any(|l| l.contains("store_flatten_bytes_per_iter")));
        assert!(bad
            .iter()
            .any(|l| l.contains("serve_throughput[") && l.contains("k=8")));
        // and run_check turns that into a hard error
        assert!(run_check(&fresh, &base).is_err());
        std::fs::remove_dir_all(&fresh).ok();
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn boundary_is_exactly_25_percent() {
        assert!(!is_regression(1.25, 1.0, false), "exactly on the line passes");
        assert!(is_regression(1.2501, 1.0, false));
        assert!(!is_regression(0.75, 1.0, true));
        assert!(is_regression(0.7499, 1.0, true));
        // zero baselines: zero stays fine, any real increase trips
        assert!(!is_regression(0.0, 0.0, false));
        assert!(is_regression(4.0, 0.0, false));
    }

    #[test]
    fn missing_baseline_passes_with_notice() {
        let fresh = dir("nobase_fresh");
        let base = dir("nobase_base");
        std::fs::write(fresh.join("BENCH_5.json"), summary(0.5, 0.0, 1000.0, 900.0))
            .unwrap();
        // empty baseline dir: everything unbaselined, nothing regressed
        let report = check_dirs(&fresh, &base).unwrap();
        assert_eq!(report.checks.len(), 0);
        assert_eq!(report.unbaselined.len(), 3);
        assert!(run_check(&fresh, &base).is_ok());
        // nonexistent baseline dir behaves the same
        std::fs::remove_dir_all(&base).ok();
        assert!(run_check(&fresh, &base).is_ok());
        std::fs::remove_dir_all(&fresh).ok();
    }

    /// ISSUE 8: the stepper-pool surface gates on BOTH coordinates —
    /// the k=8,steppers=8 payoff cell regressing must fail even when
    /// every other steppers cell (and the legacy steppers-free k=8 row)
    /// holds, and steppers must render as a coordinate, not a metric.
    #[test]
    fn steppers_cell_is_gated_by_both_coordinates() {
        let s8 = |sps_s1: f64, sps_s8: f64| {
            format!(
                concat!(
                    "{{\"pr\": 8, \"bench\": \"bench_estimation\", \"rows\": [\n",
                    "  {{\"section\": \"serve_throughput\", \"k\": 8, \"steppers\": 1, ",
                    "\"d\": 2000, \"steps_per_sec\": {}}},\n",
                    "  {{\"section\": \"serve_throughput\", \"k\": 8, \"steppers\": 8, ",
                    "\"d\": 2000, \"steps_per_sec\": {}}},\n",
                    "  {{\"section\": \"serve_throughput\", \"k\": 1, \"steppers\": 8, ",
                    "\"d\": 2000, \"steps_per_sec\": 500.0}}\n",
                    "]}}\n"
                ),
                sps_s1, sps_s8
            )
        };
        let fresh = dir("steppers_fresh");
        let base = dir("steppers_base");
        std::fs::write(base.join("BENCH_8.json"), s8(1000.0, 2500.0)).unwrap();
        // the concurrent win collapses back to serial; the serial row holds
        std::fs::write(fresh.join("BENCH_8.json"), s8(1000.0, 1000.0)).unwrap();
        let report = check_dirs(&fresh, &base).unwrap();
        let bad: Vec<&str> = report.regressions().map(|c| c.label.as_str()).collect();
        assert_eq!(bad.len(), 1, "{bad:?}");
        assert!(
            bad[0].contains("k=8") && bad[0].contains("steppers=8"),
            "{bad:?}"
        );
        // k=1,steppers=8 is not pinned; both k=8 rows were checked
        assert_eq!(report.checks.len(), 2);
        assert!(run_check(&fresh, &base).is_err());
        std::fs::remove_dir_all(&fresh).ok();
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn write_baseline_then_check_is_clean() {
        let fresh = dir("seed_fresh");
        let base = dir("seed_base");
        std::fs::write(fresh.join("BENCH_5.json"), summary(0.5, 0.0, 1000.0, 900.0))
            .unwrap();
        write_baseline(&fresh, &base).unwrap();
        let report = check_dirs(&fresh, &base).unwrap();
        assert_eq!(report.checks.len(), 3);
        assert_eq!(report.regressions().count(), 0);
        assert!(report.unbaselined.is_empty());
        std::fs::remove_dir_all(&fresh).ok();
        std::fs::remove_dir_all(&base).ok();
    }
}
