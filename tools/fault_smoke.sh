#!/usr/bin/env bash
# ISSUE 7 satellite: release-mode fault smoke against a REAL server
# process. A server started with an injected `ckpt_torn@s1` tears
# session 1's suspend checkpoint exactly the way a crash landing
# mid-write would; the server is then SIGKILLed and a successor must
# `--adopt` the manifest and still recover the session — the torn file
# is detected at resume, discarded under the stray-checkpoint rule
# (iters = 0), and the session re-runs from its seed to Done.
#
# The bit-identity of that recovery is pinned by the golden corpus
# (scenarios/faults/torn_ckpt_adopt.toml); this script asserts the
# real-process half: kill -9, process restart, wire-level recovery.
#
# Usage: tools/fault_smoke.sh [path-to-optex-binary]
set -euo pipefail

BIN="${1:-target/release/optex}"
DIR="$(mktemp -d /tmp/optex_fault_smoke.XXXXXX)"
PORT=$((20000 + RANDOM % 20000))
ADDR="127.0.0.1:${PORT}"
SERVER_PID=""

cleanup() {
  [ -n "${SERVER_PID}" ] && kill -9 "${SERVER_PID}" 2>/dev/null || true
  rm -rf "${DIR}"
}
trap cleanup EXIT

fail() { echo "fault_smoke: FAIL: $*" >&2; exit 1; }

# One JSONL request/response exchange over bash's /dev/tcp (no netcat
# dependency on the runner).
request() {
  local req="$1" reply
  exec 3<>"/dev/tcp/127.0.0.1/${PORT}" || fail "connecting ${ADDR}"
  printf '%s\n' "${req}" >&3
  IFS= read -r reply <&3 || fail "no reply to: ${req}"
  exec 3<&- 3>&-
  printf '%s' "${reply}"
}

wait_port() {
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/${PORT}") 2>/dev/null; then
      exec 3<&- 3>&- 2>/dev/null || true
      return 0
    fi
    sleep 0.1
  done
  fail "server never came up on ${ADDR}"
}

echo "fault_smoke: phase 1 — server with injected torn-checkpoint write"
"${BIN}" serve --addr "${ADDR}" --threads 1 \
  --faults 'ckpt_torn@s1' \
  --set "serve.ckpt_dir=${DIR}" &
SERVER_PID=$!
wait_port

# paused admission: the suspend checkpoint is session 1's FIRST write,
# which the injected fault truncates mid-file
REPLY=$(request '{"cmd":"submit","config":{"workload":"rosenbrock","synth_dim":64,"steps":6,"seed":9,"optex.threads":1},"paused":true}')
echo "fault_smoke: submit -> ${REPLY}"
case "${REPLY}" in
  *'"state":"paused"'*) ;;
  *) fail "paused submit not acknowledged: ${REPLY}" ;;
esac
[ -s "${DIR}/session_1.ckpt" ] || fail "suspend checkpoint was never written"
[ -s "${DIR}/manifest.jsonl" ] || fail "manifest was never written"

echo "fault_smoke: phase 2 — SIGKILL the server with the torn write on disk"
kill -9 "${SERVER_PID}"
wait "${SERVER_PID}" 2>/dev/null || true
SERVER_PID=""

echo "fault_smoke: phase 3 — successor adopts and recovers the session"
"${BIN}" serve --addr "${ADDR}" --threads 1 --adopt \
  --set "serve.ckpt_dir=${DIR}" &
SERVER_PID=$!
wait_port

REPLY=$(request '{"cmd":"status","id":1}')
echo "fault_smoke: adopted status -> ${REPLY}"
case "${REPLY}" in
  *'"state":"paused"'*) ;;
  *) fail "adopted session not paused: ${REPLY}" ;;
esac

# resume: the torn checkpoint fails to restore, is discarded (iters = 0
# stray-checkpoint rule), and the session re-runs from its seed
REPLY=$(request '{"cmd":"resume","id":1}')
echo "fault_smoke: resume -> ${REPLY}"
case "${REPLY}" in
  *'"ok":true'*) ;;
  *) fail "resume refused — torn checkpoint was not recovered: ${REPLY}" ;;
esac

for _ in $(seq 1 300); do
  REPLY=$(request '{"cmd":"status","id":1}')
  case "${REPLY}" in
    *'"state":"done"'*) break ;;
    *'"state":"failed"'*) fail "session failed after adopt: ${REPLY}" ;;
  esac
  sleep 0.1
done
case "${REPLY}" in
  *'"state":"done"'*) ;;
  *) fail "session never finished after adopt: ${REPLY}" ;;
esac
case "${REPLY}" in
  *'"iters":6'*) ;;
  *) fail "recovered session did not run the full budget: ${REPLY}" ;;
esac

REPLY=$(request '{"cmd":"shutdown"}')
echo "fault_smoke: shutdown -> ${REPLY}"
wait "${SERVER_PID}" 2>/dev/null || true
SERVER_PID=""

echo "fault_smoke: OK — torn write + SIGKILL recovered via --adopt"
